"""Quickstart: build an assigned architecture, run a train step, then
serve a few tokens — the whole public API in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py [--arch deepseek-7b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.training import (AdamWConfig, TrainConfig, init_state,
                            make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b",
                    choices=list(ASSIGNED_ARCHS))
    args = ap.parse_args()

    # reduced variant of the assigned config (full configs are for the
    # dry-run: python -m repro.launch.dryrun --arch <id> --shape <s>)
    cfg = reduced(get_config(args.arch))
    print(f"arch={cfg.name} type={cfg.arch_type} "
          f"full-size params={get_config(args.arch).param_count() / 1e9:.1f}B")

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # one training step
    step = jax.jit(make_train_step(model, TrainConfig(
        adamw=AdamWConfig(warmup_steps=1, total_steps=10))))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab_size)}
    if cfg.arch_type == "audio":
        batch["frames"] = jnp.zeros((2, cfg.encoder_seq_len, cfg.d_model),
                                    jnp.bfloat16)
    if cfg.arch_type == "vlm":
        batch["prefix"] = jnp.zeros((2, cfg.num_prefix_embeddings,
                                     cfg.d_model), jnp.bfloat16)
    batch["labels"] = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    params, _, metrics = step(params, init_state(params), batch)
    print(f"train step: loss={float(metrics['loss']):.3f}")

    # serve a couple of requests (text-only archs)
    if cfg.arch_type not in ("audio", "vlm"):
        engine = ServingEngine(model, params, slots=2, max_len=64)
        reqs = [Request(uid=i, prompt=np.arange(5, dtype=np.int32) + 1,
                        max_new_tokens=8) for i in range(3)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        print(f"served {engine.stats.tokens_generated} tokens; "
              f"sample output: {reqs[0].output}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
