"""The paper's §7.5 'hardware-aware execution strategy' as a tool:
for each assigned architecture × input shape, print the planner's
per-GEMM decisions (precision, kernel path, fusion) with the
arithmetic-intensity napkin math that justifies them.

  PYTHONPATH=src python examples/hardware_aware_plan.py --arch kimi-k2-1t-a32b
"""
import argparse

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.core import TPU_V5E, plan
from repro.core.cost_model import a17_cpu


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b",
                    choices=list(ASSIGNED_ARCHS))
    ap.add_argument("--hw", default="tpu", choices=["tpu", "a17"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    hw = TPU_V5E if args.hw == "tpu" else a17_cpu(4)
    print(f"hardware={hw.name} ridge={hw.ridge_flops_per_byte:.0f} "
          f"FLOP/byte\n")
    for shape in INPUT_SHAPES.values():
        p = plan(cfg, shape, hw)
        print(p.summary())
        print(f"  -> config overrides: {p.config_overrides()}\n")


if __name__ == "__main__":
    main()
