"""End-to-end driver (deliverable b): train a ~100M-param llama-family
model for a few hundred steps on the synthetic LM stream and watch the
loss drop.

  PYTHONPATH=src python examples/train_small.py --steps 300

On this CPU container the default is a ~10M model / 60 steps so the
example finishes in minutes; pass --full for the 100M x 300-step run.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.training import (AdamWConfig, DataConfig, TrainConfig, batches,
                            checkpoint, init_state, make_train_step)


def model_config(full: bool) -> ModelConfig:
    if full:
        # ~100M params: 12L, d_model 640, llama-style
        return ModelConfig(name="repro-100m", num_layers=12, d_model=640,
                           num_heads=10, num_kv_heads=5, head_dim=64,
                           d_ff=1792, vocab_size=32768, param_dtype="f32",
                           remat=False, max_seq_len=1024)
    return ModelConfig(name="repro-10m", num_layers=4, d_model=256,
                       num_heads=4, num_kv_heads=2, head_dim=64,
                       d_ff=704, vocab_size=4096, param_dtype="f32",
                       remat=False, max_seq_len=512)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_small.msgpack")
    args = ap.parse_args()

    cfg = model_config(args.full)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n / 1e6:.1f}M params")

    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=20,
                                         total_steps=args.steps,
                                         weight_decay=0.01))
    step = jax.jit(make_train_step(model, tcfg))
    opt = init_state(params)
    data = batches(DataConfig(vocab_size=cfg.vocab_size,
                              seq_len=args.seq_len,
                              global_batch=args.batch, kind="lm"))

    t0 = time.time()
    first = None
    for i in range(args.steps):
        b = next(data)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in b.items()})
        if first is None:
            first = float(m["loss"])
        if i % 10 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tok_s = (i + 1) * args.batch * args.seq_len / dt
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"lr {float(m['lr']):.2e} gnorm "
                  f"{float(m['grad_norm']):.2f} ({tok_s:.0f} tok/s)")
    last = float(m["loss"])
    checkpoint.save(args.ckpt, {"params": params, "config": cfg.name})
    print(f"loss {first:.3f} -> {last:.3f}; checkpoint at {args.ckpt}")
    assert last < first, "loss did not decrease"


if __name__ == "__main__":
    main()
