"""Batched serving example (the paper's decode workload, deployed):
load (or train-then-quantize) a small model and serve a stream of
requests through the continuous-batching engine at Q8/Q4 — the paper's
precision sweep as a deployment decision.

  PYTHONPATH=src python examples/serve_batch.py --quant q4_0

With ``--frontend`` the same stream runs through the asyncio actor
front-end (``repro.launch.serve.AsyncServingFrontend``) instead of the
blocking ``engine.run()`` — the deployment shape for interactive
serving. The front-end API in one screen::

    fe = AsyncServingFrontend(engine, max_pending=8)

    # await the full greedy/sampled completion
    toks = await fe.generate(prompt, max_new_tokens=24)

    # stream tokens as megastep blocks drain; enforce a deadline —
    # on expiry generate() raises DeadlineExceeded carrying the
    # partial tokens, and the request's slot retires in the engine
    # via the frozen-write cancel path
    try:
        toks = await fe.generate(prompt, max_new_tokens=24,
                                 deadline_s=0.5,
                                 on_token=lambda t: print(t, end=" "),
                                 temperature=0.7, top_k=40)
    except DeadlineExceeded as e:
        partial = e.tokens

    await fe.close()        # drain staged work, stop the serve loop

One coroutine owns the engine, so ``generate`` is safe to call from
any number of concurrent tasks; ``max_pending`` bounds how many
admitted-but-unfinished requests exist at once (further ``generate``
calls suspend — backpressure, not an error). Cancelling the awaiting
task (``task.cancel()``) cancels the request in the engine too.

  PYTHONPATH=src python examples/serve_batch.py --frontend --deadline-s 2

Failure semantics (the overload-PR contract — every outcome is typed
and observable; overload is a steady state, not a crash)::

    # shed at admission, before holding any resource: subclasses of
    # serving.SubmitReject (a ValueError)
    try:
        engine.submit(req)
    except QueueFull as e:          # max_queue bound hit
        sleep(e.retry_after_s or 0.1); resubmit()
    except InfeasibleDeadline:      # deadline < service even unqueued
        drop()                      # no tokens it could ever use
    except PromptTooLong:           # can never fit the cache
        truncate_or_raise_max_len()

    # preempted under pool pressure: evicted, requeued, resumed by
    # re-prefilling prompt + generated prefix — token-identical under
    # greedy sampling; req.preemptions counts evictions
    # poisoned (NaN/inf logits): the slot freezes its cache and
    # retires with req.error == "nonfinite-logits"; co-batched
    # requests' streams are untouched (byte-identical)

    # the asyncio front-end surfaces the same outcomes per call:
    # Backpressure (with retry_after_s) for QueueFull, RequestFailed
    # for error-retired requests, DeadlineExceeded (partial tokens)
    # for expired deadlines, ValueError for the other rejects

``engine.audit()`` (or ``launch.serve --audit``, per step) asserts the
block-pool/queue/slot invariants; ``serving.FaultInjector`` replays
seeded fault schedules against all of the above deterministically.
"""
import argparse
import asyncio
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serve import AsyncServingFrontend, DeadlineExceeded
from repro.models import Model
from repro.serving import Request, SamplingConfig, ServingEngine
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", "--precision", dest="quant",
                    default="q8_0", choices=["bf16", "q8_0", "q4_0"],
                    help="serving weight precision (paper §5.3; "
                         "--precision kept as a back-compat alias)")
    ap.add_argument("--kv-quant", dest="kv_quant", default="bf16",
                    choices=["bf16", "q8_0", "q4_0"],
                    help="KV-cache precision: groupwise int8 payload + "
                         "scales per ring-buffer position (the decode "
                         "stream that grows with context; no-op for "
                         "recurrent families)")
    # When does paging pay? When requests share prompt prefixes (a
    # system prompt, few-shot examples): the prefix cache maps the
    # shared pages into every hitting slot and skips re-prefilling
    # them. And when the dense slots*max_len prealloc overshoots what
    # is actually live: the pool only holds pages in use. It costs a
    # per-step block-table gather, so for short-context streams with
    # no reuse, dense (--page-size 0) is the right default —
    # dispatch.plan(prefix_hit_rate=...) makes the same call from the
    # analytic twin (scheduler.simulate_paging).
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV-cache page size in tokens; 0 = dense "
                         "(see note above on when paging pays)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share prompt-prefix pages across requests "
                         "(the example stream reuses a common prefix "
                         "so hits actually occur)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--frontend", action="store_true",
                    help="serve through the asyncio front-end "
                         "(streaming callbacks, deadlines) instead of "
                         "engine.run()")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline for --frontend")
    args = ap.parse_args()

    cfg = reduced(get_config("mistral-nemo-12b"), num_layers=4,
                  d_model=256, d_ff=512)
    model_cfg = dataclasses.replace(cfg, quant_policy=args.quant,
                                    kv_quant=args.kv_quant)
    model = Model(model_cfg)
    params = model.init(jax.random.PRNGKey(0), quantize=False)
    if args.quant != "bf16":
        print(f"serving with {args.quant} weights "
              f"(paper: Q4 = 4.5 bits/weight)")
    if args.kv_quant != "bf16":
        print(f"serving with a {args.kv_quant} KV cache "
              f"(cache bytes x {8.5 / 16 if args.kv_quant == 'q8_0' else 4.5 / 16:.3f})")

    # the engine quantizes the weight pytree on entry per quant_policy;
    # kv_quant stores cache leaves as int8 payload + groupwise scales
    engine = ServingEngine(model, params, slots=args.slots, max_len=256,
                           sampling=SamplingConfig(temperature=0.7,
                                                   top_k=40),
                           quant_policy=args.quant,
                           kv_quant=args.kv_quant,
                           page_size=args.page_size,
                           prefix_cache=args.prefix_cache)
    rng = np.random.default_rng(0)
    # with --prefix-cache, every request opens with the same "system
    # prompt" so the shared pages actually hit; tails stay unique
    shared = (rng.integers(1, cfg.vocab_size, size=2 * args.page_size
                           + 1).astype(np.int32)
              if args.prefix_cache and args.page_size else
              np.zeros(0, np.int32))
    prompts = [np.concatenate([
                   shared,
                   rng.integers(1, cfg.vocab_size,
                                size=5 + i % 4).astype(np.int32)])
               for i in range(args.requests)]

    t0 = time.time()
    if args.frontend:
        async def drive():
            fe = AsyncServingFrontend(engine,
                                      max_pending=2 * args.slots)

            async def one(p):
                try:
                    return await fe.generate(
                        p, max_new_tokens=args.max_new,
                        deadline_s=args.deadline_s,
                        temperature=0.7, top_k=40)
                except DeadlineExceeded as e:
                    return e            # keep the partial tokens
            outs = await asyncio.gather(*[one(p) for p in prompts])
            await fe.close()
            return outs

        outs = asyncio.run(drive())
        dt = time.time() - t0
        expired = sum(isinstance(o, DeadlineExceeded) for o in outs)
        first = next((o for o in outs
                      if not isinstance(o, DeadlineExceeded)), [])
        print(f"{len(outs) - expired}/{len(outs)} requests done, "
              f"{expired} deadline-expired, "
              f"{engine.stats.tokens_generated} tokens in {dt:.1f}s "
              f"({engine.stats.tokens_generated / dt:.1f} tok/s)")
        print("sample:", list(first)[:12])
        return

    reqs = [Request(uid=i, prompt=p, max_new_tokens=args.max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    print(f"{done}/{len(reqs)} requests done, "
          f"{engine.stats.tokens_generated} tokens in {dt:.1f}s "
          f"({engine.stats.tokens_generated / dt:.1f} tok/s, "
          f"{engine.stats.steps} batched decode steps)")
    if engine.page_size:
        print(f"paging: {engine.cache_blocks} blocks x "
              f"{engine.page_size} tokens, {engine.stats.prefix_hits} "
              f"prefix hits ({engine.stats.prefix_hit_tokens} prompt "
              f"tokens skipped)")
    print("sample:", reqs[0].output[:12])


if __name__ == "__main__":
    main()
