"""Batched serving example (the paper's decode workload, deployed):
load (or train-then-quantize) a small model and serve a stream of
requests through the continuous-batching engine at Q8/Q4 — the paper's
precision sweep as a deployment decision.

  PYTHONPATH=src python examples/serve_batch.py --quant q4_0
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, SamplingConfig, ServingEngine
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", "--precision", dest="quant",
                    default="q8_0", choices=["bf16", "q8_0", "q4_0"],
                    help="serving weight precision (paper §5.3; "
                         "--precision kept as a back-compat alias)")
    ap.add_argument("--kv-quant", dest="kv_quant", default="bf16",
                    choices=["bf16", "q8_0", "q4_0"],
                    help="KV-cache precision: groupwise int8 payload + "
                         "scales per ring-buffer position (the decode "
                         "stream that grows with context; no-op for "
                         "recurrent families)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config("mistral-nemo-12b"), num_layers=4,
                  d_model=256, d_ff=512)
    model_cfg = dataclasses.replace(cfg, quant_policy=args.quant,
                                    kv_quant=args.kv_quant)
    model = Model(model_cfg)
    params = model.init(jax.random.PRNGKey(0), quantize=False)
    if args.quant != "bf16":
        print(f"serving with {args.quant} weights "
              f"(paper: Q4 = 4.5 bits/weight)")
    if args.kv_quant != "bf16":
        print(f"serving with a {args.kv_quant} KV cache "
              f"(cache bytes x {8.5 / 16 if args.kv_quant == 'q8_0' else 4.5 / 16:.3f})")

    # the engine quantizes the weight pytree on entry per quant_policy;
    # kv_quant stores cache leaves as int8 payload + groupwise scales
    engine = ServingEngine(model, params, slots=args.slots, max_len=256,
                           sampling=SamplingConfig(temperature=0.7,
                                                   top_k=40),
                           quant_policy=args.quant,
                           kv_quant=args.kv_quant)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=5 + i % 4).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)

    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    print(f"{done}/{len(reqs)} requests done, "
          f"{engine.stats.tokens_generated} tokens in {dt:.1f}s "
          f"({engine.stats.tokens_generated / dt:.1f} tok/s, "
          f"{engine.stats.steps} batched decode steps)")
    print("sample:", reqs[0].output[:12])


if __name__ == "__main__":
    main()
