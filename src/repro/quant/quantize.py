"""Groupwise weight quantization — llama.cpp k-quant analogues.

Formats (paper §4.2):
- ``q8_0``: groups of 32 along the reduction dim; int8 payload + one
  f16-ish scale per group → 8.5 bits/weight.
- ``q4_0``: groups of 32; symmetric int4 in [-8, 7], two nibbles packed
  per int8 byte → 4.5 bits/weight (the paper's footnote).

A ``QuantizedTensor`` is a pytree (works inside jit / pjit / scan), so
quantized models shard and checkpoint exactly like bf16 ones. The
packed layout matches what ``kernels/quant_matmul.py`` consumes: the
reduction dim K is the second-to-last axis, scales have shape
``K//group`` on that axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Groupwise-quantized 2-D (or stacked 3-D) weight.

    data:   int8. q8_0 → shape (..., K, N); q4_0 → (..., K//2, N) packed.
    scales: activation-dtype, shape (..., K//group, N broadcast? no:
            (..., K//group, N)) — per (group, column) scale, llama.cpp
            row-major k-quant transposed to column-major matmul layout.

    The logical (unquantized) shape is *derived* from the live ``data``
    array, never stored: a stacked (L, K, N) weight that rides through a
    scan-over-layers loses its leading dim on the pytree children each
    iteration, and any statically-stored shape would go stale (jit
    transforms carry aux data through unchanged). ``shape`` /
    ``logical_shape`` therefore always describe the tensor as it is now.
    """
    data: jax.Array
    scales: jax.Array
    fmt: str            # "q8_0" | "q4_0"
    group: int = 32

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.scales), (self.fmt, self.group)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scales = children
        fmt, group = aux
        return cls(data, scales, fmt, group)

    @property
    def dtype(self):
        return self.scales.dtype

    @property
    def logical_shape(self) -> Tuple[int, ...]:
        """Logical (unquantized) shape ``(..., K, N)`` derived from the
        *current* data array — authoritative under any slicing (scan
        over stacked layers, vmap, manual ``data[i]`` indexing)."""
        k2 = self.data.shape[-2]
        K = 2 * k2 if self.fmt == "q4_0" else k2
        return tuple(self.data.shape[:-2]) + (K, self.data.shape[-1])

    @property
    def shape(self) -> Tuple[int, ...]:
        """Alias of :attr:`logical_shape` (ndarray-duck-typed)."""
        return self.logical_shape

    @property
    def ndim(self):
        return self.data.ndim

    @property
    def k_axis(self) -> int:
        return self.data.ndim - 2

    @property
    def logical_nbytes(self) -> int:
        import numpy as np
        return int(np.prod(self.logical_shape)) * 2

    @property
    def quant_nbytes(self) -> int:
        return self.data.size * self.data.dtype.itemsize + \
            self.scales.size * self.scales.dtype.itemsize


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4 values in [-8,7] pairwise along axis -2 into int8.

    Element (2i, n) goes to the low nibble of packed (i, n); (2i+1, n)
    to the high nibble.
    """
    assert q.shape[-2] % 2 == 0, q.shape
    lo = q[..., 0::2, :] & 0x0F
    hi = q[..., 1::2, :] & 0x0F
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` → int8 values in [-8, 7]."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    # sign-extend 4-bit two's complement
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    k2 = packed.shape[-2]
    out_shape = packed.shape[:-2] + (2 * k2,) + packed.shape[-1:]
    # interleave: stack -> (..., k2, 2, n), row-major reshape -> (..., 2*k2, n)
    out = jnp.stack([lo, hi], axis=-2)
    return out.reshape(out_shape)


def _group_scales(w: jax.Array, group: int, qmax: float):
    *lead, K, N = w.shape
    assert K % group == 0, (K, group)
    wg = w.reshape(*lead, K // group, group, N)
    amax = jnp.max(jnp.abs(wg), axis=-2)          # (..., K//group, N)
    scale = (amax / qmax).astype(jnp.float32)
    scale = jnp.where(scale == 0, 1.0, scale)
    return wg, scale


def quantize_q8_0(w: jax.Array, group: int = 32) -> QuantizedTensor:
    wg, scale = _group_scales(w.astype(jnp.float32), group, 127.0)
    q = jnp.clip(jnp.round(wg / scale[..., None, :]), -127, 127)
    q = q.astype(jnp.int8).reshape(w.shape)
    return QuantizedTensor(q, scale.astype(jnp.bfloat16), "q8_0", group)


def quantize_q4_0(w: jax.Array, group: int = 32) -> QuantizedTensor:
    wg, scale = _group_scales(w.astype(jnp.float32), group, 7.0)
    q = jnp.clip(jnp.round(wg / scale[..., None, :]), -8, 7)
    q = q.astype(jnp.int8).reshape(w.shape)
    return QuantizedTensor(pack_int4(q), scale.astype(jnp.bfloat16),
                           "q4_0", group)


def quantize(w: jax.Array, fmt: str, group: int = 32):
    if fmt in ("bf16", "f16", "f32"):
        return w
    if fmt == "q8_0":
        return quantize_q8_0(w, group)
    if fmt == "q4_0":
        return quantize_q4_0(w, group)
    raise ValueError(fmt)


def dequantize(qt: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    if qt.fmt == "q8_0":
        q = qt.data
    elif qt.fmt == "q4_0":
        q = unpack_int4(qt.data)
    else:
        raise ValueError(qt.fmt)
    *lead, K, N = qt.logical_shape
    qg = q.reshape(*lead, K // qt.group, qt.group, N).astype(jnp.float32)
    w = qg * qt.scales[..., None, :].astype(jnp.float32)
    return w.reshape(*lead, K, N).astype(dtype)


# ---------------------------------------------------------------------------
# Row-wise (last-axis) groupwise quantization — KV-cache leaves
# ---------------------------------------------------------------------------
#
# Weights quantize along the reduction dim (axis -2, the matmul K); KV
# cache entries quantize along the *feature* dim (axis -1, head_dim):
# each cached position is written once and read many times, so the
# scale must be local to the row being written — a plain (payload,
# scales) array pair rather than a QuantizedTensor, because the two
# arrays live as sibling leaves of the cache pytree (k / k_scale) and
# ride scan / donate_argnums / cache_axes splicing like any other leaf.
# All helpers are pure jnp and shape-static: callable from inside jit
# (the cache-write point in ``decode_step`` / ``prefill``).

def kv_group_size(dim: int, group: int, fmt: str) -> int:
    """Effective group size for quantizing a ``dim``-wide row: the
    largest divisor of ``dim`` that is <= ``group`` (head dims are not
    always multiples of 32). q4_0 additionally needs ``dim`` even to
    nibble-pack pairs along the row."""
    if fmt == "q4_0" and dim % 2:
        raise ValueError(
            f"q4_0 KV rows need an even dim to pack nibbles (got {dim})")
    g = min(group, dim)
    while dim % g:
        g -= 1
    return g


def pack_int4_rows(q: jax.Array) -> jax.Array:
    """Pack int4 values in [-8, 7] pairwise along the LAST axis."""
    assert q.shape[-1] % 2 == 0, q.shape
    lo = q[..., 0::2] & 0x0F
    hi = q[..., 1::2] & 0x0F
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4_rows(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4_rows` → int8 values in [-8, 7]."""
    lo = (packed & 0x0F).astype(jnp.int8)
    hi = ((packed >> 4) & 0x0F).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(packed.shape[:-1] + (2 * packed.shape[-1],))


def quantize_rows(x: jax.Array, fmt: str, group: int = 32
                  ) -> Tuple[jax.Array, jax.Array]:
    """Groupwise-quantize along the last axis.

    x: (..., d) → (payload int8 (..., d) [q8_0] or (..., d//2) [q4_0],
    scales bf16 (..., d // g)) with ``g = kv_group_size(d, group, fmt)``.

    Determinism note: bf16 inputs are dyadic, so ``x / scale`` lands on
    exact .5 ties often (the group max maps to qmax exactly). XLA-CPU's
    compiled division (reciprocal-multiply under fast-math) can break
    such ties one ulp differently from the eager op — so compare
    quantized payloads *within* one compilation regime (the serving
    engine and ``reference_decode`` are both jitted, which is why their
    cache leaves match bit-exactly; an eager recomputation may differ
    by one quantization step on tie elements).
    """
    d = x.shape[-1]
    g = kv_group_size(d, group, fmt)
    qmax = 127.0 if fmt == "q8_0" else 7.0
    if fmt not in ("q8_0", "q4_0"):
        raise ValueError(fmt)
    xg = x.astype(jnp.float32).reshape(x.shape[:-1] + (d // g, g))
    amax = jnp.max(jnp.abs(xg), axis=-1)
    scale = (amax / qmax).astype(jnp.float32)
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xg / scale[..., None]), -qmax, qmax)
    q = q.astype(jnp.int8).reshape(x.shape)
    if fmt == "q4_0":
        q = pack_int4_rows(q)
    return q, scale.astype(jnp.bfloat16)


def dequantize_rows(payload: jax.Array, scales: jax.Array, fmt: str,
                    dtype=jnp.bfloat16) -> jax.Array:
    """Inverse of :func:`quantize_rows` (group size inferred from the
    scales' last dim)."""
    if fmt == "q4_0":
        q = unpack_int4_rows(payload)
    elif fmt == "q8_0":
        q = payload
    else:
        raise ValueError(fmt)
    d = q.shape[-1]
    g = d // scales.shape[-1]
    qg = q.reshape(q.shape[:-1] + (d // g, g)).astype(jnp.float32)
    x = qg * scales[..., None].astype(jnp.float32)
    return x.reshape(q.shape).astype(dtype)


def quantize_tree(params, fmt: str, group: int = 32,
                  predicate=None):
    """Quantize every matmul weight in a param pytree.

    Default selection: leaves with ndim >= 2 and K (axis -2) divisible
    by ``group``, *excluding* any path containing ``norm`` or ``embed``.
    Norm scales/biases are sub-2-D or precision-critical; embedding
    tables (and the tied ``lm_head`` when it shares the ``embed`` path)
    are read row-wise by gather, not streamed through ``quant_matmul``'s
    K-major tiling, so they stay bf16. Pass ``predicate(path, leaf) ->
    bool`` to additionally restrict the selection (it cannot re-enable
    a skipped path; tests/test_quant.py pins exactly which leaves of a
    dense model quantize).
    """
    if fmt in ("bf16", "f16", "f32"):
        return params

    def maybe_quant(path, leaf):
        if isinstance(leaf, QuantizedTensor):
            return leaf
        pred_ok = predicate is None or predicate(path, leaf)
        path_str = jax.tree_util.keystr(path)
        is_weight = (getattr(leaf, "ndim", 0) >= 2
                     and leaf.shape[-2] % group == 0
                     and "embed" not in path_str
                     and "norm" not in path_str)
        if pred_ok and is_weight:
            return quantize(leaf, fmt, group)
        return leaf

    # is_leaf stops traversal AT QuantizedTensor nodes: without it,
    # tree_map descends into their (data, scales) children and
    # re-quantizes the int8 payload itself — the idempotency the
    # isinstance() check above promises would silently never trigger
    return jax.tree_util.tree_map_with_path(
        maybe_quant, params,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))
