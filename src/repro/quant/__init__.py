from repro.quant.quantize import (
    QuantizedTensor,
    quantize_q8_0,
    quantize_q4_0,
    dequantize,
    quantize,
    pack_int4,
    unpack_int4,
    quantize_tree,
)

__all__ = [
    "QuantizedTensor", "quantize_q8_0", "quantize_q4_0", "dequantize",
    "quantize", "pack_int4", "unpack_int4", "quantize_tree",
]
