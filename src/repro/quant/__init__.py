from repro.quant.quantize import (
    QuantizedTensor,
    quantize_q8_0,
    quantize_q4_0,
    dequantize,
    quantize,
    pack_int4,
    unpack_int4,
    quantize_tree,
    kv_group_size,
    quantize_rows,
    dequantize_rows,
    pack_int4_rows,
    unpack_int4_rows,
)

__all__ = [
    "QuantizedTensor", "quantize_q8_0", "quantize_q4_0", "dequantize",
    "quantize", "pack_int4", "unpack_int4", "quantize_tree",
    "kv_group_size", "quantize_rows", "dequantize_rows",
    "pack_int4_rows", "unpack_int4_rows",
]
