"""Hardware-aware execution planner (paper §6.1/§7.5, made first-class).

The paper's closing recommendation is a "hardware-aware execution
strategy that effectively balances computation across available
resources". This module implements it as a *planner*: given a model
config, a target input shape and a hardware spec, choose

- weight precision per GEMM class (memory-bound GEMVs want Q4/Q8;
  compute-bound prefill GEMMs can stay bf16),
- fusion (always on when any GEMM class is dispatch/latency-bound),
- Pallas-vs-XLA kernel path per GEMM,
- the scheduler version / sharding ruleset.

Decisions are napkin-math driven off arithmetic intensity vs. the
hardware ridge point — the same logic as the paper's CPU-vs-GPU
reasoning (small GEMVs don't amortize launch overhead; on TPU, low-AI
GEMMs don't amortize HBM reads unless weights are quantized).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, InputShape
from repro.core import cost_model as cm
from repro.core.graph import Graph, Node, Op, build_decoder_graph
from repro.core.precision import get_format


@dataclasses.dataclass
class GemmDecision:
    tag: str
    m: float                  # tokens per step
    arithmetic_intensity: float
    bound: str                # "memory" | "compute"
    precision: str            # bf16 | q8_0 | q4_0
    use_pallas: bool
    reason: str


@dataclasses.dataclass
class ExecutionPlan:
    arch: str
    shape: str
    hardware: str
    scheduler_version: str
    fuse_qkv: bool
    fuse_gate_up: bool
    decisions: List[GemmDecision]
    # decode serving loop: tokens per fused dispatch (1 for prefill /
    # training shapes — there is no per-token loop to amortize)
    megastep_k: int = 1
    # serving admission mode: "chunked" rides prompts inside the
    # megastep scan (zero extra dispatches), "stall" runs batched
    # prefill dispatches between megasteps (wins when per-prompt
    # compute dwarfs the dispatch/stall cost, e.g. very long prompts)
    admission: str = "chunked"
    # donate cache+SlotState pytrees into the megastep
    # (jit donate_argnums): in-place carry update, ~half the carry's
    # HBM traffic at each dispatch boundary
    donate_carries: bool = True
    # serving weight precision: the coarsest format any memory-bound
    # GEMM requested, cross-checked against the analytic precision
    # sweep (scheduler.simulate_precision) — the paper's §5.3 F16-vs-Q4
    # decision, emitted as a first-class plan field the engine consumes
    # (ServingEngine(quant_policy=...))
    quant_policy: str = "bf16"
    # serving KV-cache precision (the second memory stream — grows with
    # context/batch where weights don't): groupwise-quantized cache
    # leaves, emitted for decode shapes when quality allows
    # (quality_floor_bits veto applies, same as quant_policy) and
    # cross-checked against scheduler.simulate_kv_precision at the
    # plan's context length. Always bf16 for recurrent families
    # (ssm/hybrid) — the engine treats kv_quant as a no-op there.
    kv_quant: str = "bf16"
    # serving-loop pipelining: megasteps kept in flight. 1 = serial
    # dispatch/drain; 2 = double-buffered — dispatch is async under
    # JAX, so draining megastep N's token block overlaps the device
    # running N+1 and the host gap is hidden up to the device-step
    # time (cost_model.megastep_time's overlap term). Emitted for
    # decode shapes whenever the analytic twin
    # (scheduler.simulate_async_overlap) predicts depth 2 >= depth 1;
    # 1 for prefill/training shapes (no steady-state loop to overlap).
    # Caveat measured on jax-0.4.37-CPU: depth >= 2 needs
    # donate_carries=False — donating a buffer that is itself a
    # pending megastep's output forces the jit call to execute inline,
    # serializing the very dispatch chain pipelining relies on. The
    # planner enforces the pair: any plan with depth > 1 carries
    # donate_carries=False (and ServingEngine warns + overrides if
    # handed the pathological combination directly).
    pipeline_depth: int = 1
    # paged KV cache: block size (tokens per page) of the slot->block-
    # table indirection, 0 = dense per-slot cache. Emitted for decode
    # shapes on full-attention families when scheduler.simulate_paging
    # predicts paged throughput >= dense at the traffic's prefix hit
    # rate — the gather tax is a pure cost at hit rate 0, so the knob
    # stays 0 (dense) unless prefix reuse or the memory-footprint win
    # (cache bytes scale with live tokens, not slots x max_len) pays
    # for it. Always 0 for recurrent/windowed families, where the
    # engine's paging_effective contract makes paging a structural
    # no-op. Paging itself is bit-exact (greedy token-identical,
    # pinned by the property suite) so the quality floor never vetoes
    # it directly; it composes with kv_quant, whose quality_floor_bits
    # veto above still applies to the pages' payload precision.
    page_size: int = 0
    # Overload protection (engine kwargs, not config overrides):
    # admission-queue bound — submit() past this depth sheds with a
    # typed QueueFull instead of growing the backlog without bound.
    # Emitted for decode shapes when the described arrival rate
    # exceeds the predicted service capacity (scheduler.
    # simulate_overload): an unbounded queue past saturation turns
    # every deadline into a miss as the backlog grows, so shedding at
    # ~2x the slot count keeps admitted requests' wait bounded.
    # 0 = unbounded (traffic below capacity never sheds anyway).
    max_queue: int = 0
    # paged block-pool size backing the plan (ServingEngine
    # cache_blocks kwarg), emitted alongside page_size: enough pages
    # to back every slot's prompt+decode budget plus the reserved
    # garbage block. 0 = engine default. Sizing the pool below this
    # trades memory for preemptions (pool-starved admissions evict
    # least-progress victims) — simulate_overload prices that trade.
    cache_blocks: int = 0
    # Which dequant execution the plan was priced against: "pallas"
    # (fused in-register dequant — quant_matmul + the quantized decode-
    # attention kernel) or "xla" (materialized bf16 unpack before the
    # consuming op). The backend changes the *cost* of every quantized
    # stream, so it re-ranks quant_policy / kv_quant: under "xla" the
    # q4_0 unpack tax hands both wins to q8_0 on bandwidth-rich parts.
    kernel_backend: str = "pallas"

    def config_overrides(self) -> Dict:
        """Overrides to apply to the ModelConfig for this plan."""
        # ``kernels`` wins over ``use_pallas`` in ModelConfig's
        # __post_init__, so emit the pair consistently: the fused path
        # only lights up when the plan priced it AND some GEMM wants it.
        use_pallas = (self.kernel_backend == "pallas"
                      and any(d.use_pallas for d in self.decisions))
        return dict(
            scheduler_version=self.scheduler_version,
            fuse_qkv=self.fuse_qkv,
            fuse_gate_up=self.fuse_gate_up,
            quant_policy=self.quant_policy,
            kv_quant=self.kv_quant,
            use_pallas=use_pallas,
            kernels="pallas" if use_pallas else "xla",
        )

    def summary(self) -> str:
        lines = [f"plan[{self.arch} x {self.shape} on {self.hardware}] "
                 f"sched={self.scheduler_version} fuse_qkv={self.fuse_qkv} "
                 f"fuse_gate_up={self.fuse_gate_up} "
                 f"megastep_k={self.megastep_k} "
                 f"admission={self.admission} "
                 f"depth={self.pipeline_depth} "
                 f"donate={self.donate_carries} "
                 f"page_size={self.page_size} "
                 f"max_queue={self.max_queue} "
                 f"cache_blocks={self.cache_blocks} "
                 f"quant={self.quant_policy} "
                 f"kv_quant={self.kv_quant} "
                 f"kernels={self.kernel_backend}"]
        for d in self.decisions:
            lines.append(
                f"  {d.tag:<10} AI={d.arithmetic_intensity:9.1f} "
                f"{d.bound:<7} -> {d.precision:<5} "
                f"pallas={d.use_pallas} ({d.reason})")
        return "\n".join(lines)


def plan(cfg: ModelConfig, shape: InputShape,
         hw: cm.HardwareSpec = cm.TPU_V5E, *,
         allow_quant: bool = True,
         quality_floor_bits: float = 4.5,
         arrival_rate_per_s: float = 0.0,
         avg_prompt_len: int = 0,
         max_new: int = 32,
         kernel_backend: str = "pallas",
         prefix_hit_rate: float = 0.0) -> ExecutionPlan:
    """Derive the execution plan for (arch, input shape, hardware).

    ``arrival_rate_per_s`` / ``avg_prompt_len`` / ``max_new`` describe
    the serving traffic mix (decode shapes only): they bound the
    megastep K by admission latency and pick the admission mode
    (chunked vs stall prefill) via ``scheduler.simulate_admission``.

    ``kernel_backend`` prices the plan against the fused in-register
    dequant kernels (``"pallas"``, default) or the materialized-unpack
    XLA fallback (``"xla"``). The analytic precision/KV sweeps run
    under the same backend, so the plan *predicts* the q4-vs-q8
    ordering flip the fused kernels cause: on TPU-class bandwidth an
    "xla" plan picks q8_0 (the q4 unpack tax drowns the byte win)
    while the "pallas" plan picks q4_0.

    ``prefix_hit_rate`` describes the traffic's shared-prefix rate
    (fraction of admissions whose prompt head is already cached —
    system prompts, few-shot headers). It feeds the page-size knob:
    paging's gather tax is a pure cost at hit rate 0, so the plan
    stays dense unless prefix reuse pays for the indirection (see
    ``scheduler.simulate_paging``).
    """
    if kernel_backend not in ("pallas", "xla"):
        raise ValueError(f"kernel_backend must be 'pallas' or 'xla', "
                         f"got {kernel_backend!r}")
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    ridge = hw.ridge_flops_per_byte
    seq = 1 if shape.kind == "decode" else shape.seq_len
    kv = shape.seq_len if shape.kind == "decode" else 0
    g = build_decoder_graph(cfg, seq=seq, kv_len=kv,
                            batch=shape.global_batch, fused=True)
    decisions: List[GemmDecision] = []
    for tag, nodes in sorted(g.matmuls_by_tag().items()):
        n = nodes[0]
        if not n.weight_bytes:   # activation-activation matmul (attention)
            continue
        ai = n.flops / n.bytes
        bound = "memory" if ai < ridge else "compute"
        if bound == "memory" and allow_quant:
            # memory-bound: cut weight bytes as low as quality allows
            # (a floor above 8.5 bits rules out both k-quants → bf16)
            precision = ("q4_0" if quality_floor_bits <= 4.5 else
                         "q8_0" if quality_floor_bits <= 8.5 else "bf16")
            # in-kernel (VMEM) dequant — only on the fused backend
            use_pallas = (precision != "bf16"
                          and kernel_backend == "pallas")
            reason = f"AI {ai:.0f} < ridge {ridge:.0f}: weight-bound GEMV"
        else:
            precision = "bf16"
            use_pallas = False   # XLA's MXU path is optimal for big GEMMs
            reason = f"AI {ai:.0f} >= ridge {ridge:.0f}: MXU-bound"
        decisions.append(GemmDecision(
            tag=tag, m=tokens, arithmetic_intensity=ai, bound=bound,
            precision=precision, use_pallas=use_pallas, reason=reason))

    # Fusion: always beneficial on TPU (fewer kernels, bigger GEMMs);
    # on mobile it is the paper's V1. Disabled only for v0 studies.
    version = "v2" if hw.link_bw or hw.name.startswith("tpu") else "v2"

    # One precision for all weight GEMMs: the coarsest that any
    # memory-bound GEMM requested (keeps a single param pytree).
    precisions = [d.precision for d in decisions]
    quant_policy = "q4_0" if "q4_0" in precisions else (
        "q8_0" if "q8_0" in precisions else "bf16")

    # Decode serving loop: amortize the host dispatch over K tokens —
    # the same napkin math as the AI-vs-ridge-point rule above, applied
    # to the time axis (launch cost vs per-token device time).
    megastep_k = 1
    admission = "chunked"
    kv_quant = "bf16"
    pipeline_depth = 1
    page_size = 0
    max_queue = 0
    cache_blocks = 0
    if shape.kind == "decode":
        step_s = cm.graph_time_wave(g, hw)
        megastep_k = choose_megastep_k(hw, step_s,
                                       arrival_rate_per_s=arrival_rate_per_s)
        # Admission mode under mixed prefill/decode load: ride the
        # prompt in-scan unless its one-token-per-substep cost exceeds
        # the dispatch+stall cost of a dedicated prefill (long prompts
        # on compute-rich hardware).
        from repro.core.scheduler import (simulate_admission,
                                          simulate_async_overlap,
                                          simulate_kv_precision,
                                          simulate_paging,
                                          simulate_precision)
        adm = simulate_admission(
            cfg, hw, k=megastep_k, batch=max(shape.global_batch, 1),
            prompt_len=avg_prompt_len or max(shape.seq_len, 1),
            max_new=max_new, kv_len=max(shape.seq_len, 1))
        if adm["stall"].tokens_per_s > adm["chunked"].tokens_per_s:
            admission = "stall"
        # Pipelining: double-buffer the dispatch/drain loop when the
        # overlap model says hiding the host gap behind the device
        # megastep pays (it always does once the gap is nonzero — the
        # knob exists so token-identity pins can force depth 1).
        ovl = simulate_async_overlap(
            cfg, hw, k=megastep_k, batch=max(shape.global_batch, 1),
            kv_len=max(shape.seq_len, 1), kernel_backend=kernel_backend)
        if ovl[2].tokens_per_s > ovl[1].tokens_per_s:
            pipeline_depth = 2
        if allow_quant and quant_policy != "bf16":
            # Cross-check the per-GEMM choice against the analytic
            # precision sweep: pick the fastest quality-allowed format
            # at the chosen K (the §5.3 tradeoff — the dequant tax can
            # hand the win back to Q8/F16 on compute-poor hardware).
            allowed = ["f16"] + [f for f in ("q8_0", "q4_0")
                                 if get_format(f).bits_per_weight
                                 >= quality_floor_bits]
            sweep = simulate_precision(
                cfg, hw, kv_len=max(shape.seq_len, 1),
                batch=max(shape.global_batch, 1), formats=allowed,
                ks=(megastep_k,), kernel_backend=kernel_backend)
            best = max(allowed,
                       key=lambda f: sweep[f][megastep_k].tokens_per_s)
            quant_policy = "bf16" if best == "f16" else best
        if allow_quant and cfg.arch_type not in ("ssm", "hybrid"):
            # Cache precision: same quality-floor veto as weights, then
            # pick the fastest allowed format at this plan's context
            # length and K — the cache stream only matters once kv_len
            # makes it non-negligible, which the simulator models.
            allowed_kv = ["bf16"] + [f for f in ("q8_0", "q4_0")
                                     if get_format(f).bits_per_weight
                                     >= quality_floor_bits]
            if len(allowed_kv) > 1:
                kvl = max(shape.seq_len, 1)
                kv_sweep = simulate_kv_precision(
                    cfg, hw, batch=max(shape.global_batch, 1),
                    formats=allowed_kv, ks=(megastep_k,),
                    kv_lens=(kvl,), kernel_backend=kernel_backend)
                kv_quant = max(
                    allowed_kv,
                    key=lambda f:
                        kv_sweep[f][kvl][megastep_k].tokens_per_s)
        eff_win = (cfg.sliding_window
                   or (cfg.window_long_ctx
                       if max(shape.seq_len, 1) > cfg.max_full_attn
                       else 0))
        if cfg.arch_type not in ("ssm", "hybrid") and not eff_win:
            # Page-size knob: sweep the paging model at this plan's
            # traffic mix; emit the fastest page size, vetoed back to
            # dense whenever it doesn't at least match the dense
            # throughput (at hit rate 0 the gather tax always loses,
            # so the plan pays for indirection only when prefix reuse
            # does).
            pg = simulate_paging(
                cfg, hw, slots=max(shape.global_batch, 1),
                k=megastep_k,
                prompt_len=avg_prompt_len or max(shape.seq_len, 1),
                max_new=max_new, kv_len=max(shape.seq_len, 1),
                hit_rate=prefix_hit_rate, kv_quant=kv_quant,
                kernel_backend=kernel_backend)
            best_p = max(pg, key=lambda p: pg[p]["step"].tokens_per_s)
            if best_p and (pg[best_p]["step"].tokens_per_s
                           >= pg[0]["step"].tokens_per_s):
                page_size = best_p
        if page_size:
            # pool sized to back every slot's full prompt+decode
            # budget (+1 for the reserved garbage block); shrinking
            # below this trades memory for preemptions
            slots = max(shape.global_batch, 1)
            need = (avg_prompt_len or max(shape.seq_len, 1)) + max_new
            cache_blocks = slots * (-(-need // page_size)) + 1
        if arrival_rate_per_s > 0.0:
            # Queue bound: emitted only when the described arrival
            # rate exceeds predicted service capacity — below
            # saturation an unbounded queue never grows, past it
            # shedding at ~2x slots keeps admitted waits bounded
            # (scheduler.simulate_overload's bounded-vs-unbounded
            # goodput cliff).
            from repro.core.scheduler import simulate_overload
            ov = simulate_overload(
                cfg, hw, slots=max(shape.global_batch, 1),
                k=megastep_k,
                prompt_len=avg_prompt_len or max(shape.seq_len, 1),
                max_new=max_new, page_size=page_size or 8,
                cache_blocks=cache_blocks,
                kernel_backend=kernel_backend)
            cap = ov["capacity"]
            if arrival_rate_per_s > cap["capacity_rps"]:
                max_queue = cap["queue_bound"]
    # depth >= 2 with donated carries serializes dispatch (the PR 6
    # caveat documented on the field above) — the planner must never
    # emit the pair.
    return ExecutionPlan(
        arch=cfg.name, shape=shape.name, hardware=hw.name,
        scheduler_version=version, fuse_qkv=True,
        fuse_gate_up=cfg.glu, decisions=decisions,
        megastep_k=megastep_k, admission=admission,
        donate_carries=(pipeline_depth < 2), quant_policy=quant_policy,
        kv_quant=kv_quant, pipeline_depth=pipeline_depth,
        kernel_backend=kernel_backend, page_size=page_size,
        max_queue=max_queue, cache_blocks=cache_blocks)


def choose_megastep_k(hw: cm.HardwareSpec, step_s: float, *,
                      max_k: int = 32,
                      dispatch_budget: float = 0.1,
                      arrival_rate_per_s: float = 0.0) -> int:
    """Smallest power-of-two K whose amortized per-token dispatch cost
    is ≤ ``dispatch_budget`` of the per-token device time.

    K=1 reproduces the paper's losing per-token-dispatch configuration
    (§5: the Apple GPU's 12.8 tok/s vs CPU 17); growing K trades
    retirement granularity (a finished slot idles ≤ K-1 substeps) for
    amortization, so K stops as soon as dispatch stops mattering.

    Under mixed load (``arrival_rate_per_s`` > 0), admission happens
    only at megastep boundaries, so K is additionally capped to keep
    the megastep wall within one mean inter-arrival gap — a longer
    megastep would queue arrivals behind the scan for no amortization
    gain.
    """
    if hw.dispatch_overhead_s <= 0.0 or step_s <= 0.0:
        return 1
    k = 1
    while k < max_k and hw.dispatch_overhead_s / k > \
            dispatch_budget * step_s:
        k *= 2
    if arrival_rate_per_s > 0.0:
        gap = 1.0 / arrival_rate_per_s
        while k > 1 and hw.dispatch_overhead_s + k * step_s > gap:
            k //= 2
    return k
