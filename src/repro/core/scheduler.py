"""Topological graph scheduler — the paper's §7 contribution.

Three pieces:

1. ``find_concurrent_gemms`` — analyze a :class:`Graph` for independent
   MUL_MAT sets (the paper's Fig 7 coloring: {Q,K,V} and
   {ffn_gate, ffn_up} share all inputs and no outputs).
2. ``fusion_plan`` — convert those sets into *fusions* (the TPU-native
   realization: one wide GEMM per set, see DESIGN.md §2).
3. ``simulate_version`` — predict throughput of the paper's execution
   versions V0–V3 on a given hardware spec, used by
   ``benchmarks/scheduler_versions.py`` to reproduce Figs 8–10.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core import cost_model as cm
from repro.core.graph import Graph, Node, Op, build_decoder_graph
from repro.core.precision import get_format


def _xla_unpack_penalty_s(g: Graph, weight_format: str,
                          hw: cm.HardwareSpec,
                          kernel_backend: str) -> float:
    """Per-dispatch seconds the XLA backend pays to materialize bf16
    weight views (write + read of the unpack) before the consuming
    matmuls. ``graph._mm`` bakes the *fused* dequant model into node
    costs (weight bytes at quantized width, dequant flops in-node), so
    the materialization tax must be charged here, outside the graph.
    Zero for the fused ``"pallas"`` backend and for unquantized or
    lane-convertible (q8_0) formats."""
    fmt = get_format(weight_format)
    # effective - ideal = xla_unpack_bytes/2 per weight (validates the
    # backend name as a side effect)
    extra_ratio = (fmt.effective_stream_ratio(kernel_backend)
                   - fmt.stream_ratio)
    if not extra_ratio:
        return 0.0
    weight_elems = sum(n.weight_bytes for n in g.nodes) \
        / fmt.bytes_per_weight
    # bf16 footprint x extra ratio == elems x xla_unpack_bytes_per_weight
    return weight_elems * 2.0 * extra_ratio \
        / (hw.mem_bw * hw.mem_efficiency)


@dataclasses.dataclass(frozen=True)
class ConcurrentSet:
    """A set of MUL_MAT nodes with identical deps → fusable/parallel."""
    layer: int
    block: str
    node_ids: Tuple[int, ...]
    names: Tuple[str, ...]


def find_concurrent_gemms(g: Graph) -> List[ConcurrentSet]:
    """Group matmuls that share *all* dependencies within a layer.

    This is the paper's dynamic graph analysis (§7.1 step 1): two
    matmuls with the same dep set are independent by construction and
    can be dispatched concurrently (mobile) or fused (TPU).
    """
    groups: Dict[Tuple, List[int]] = {}
    for i, n in enumerate(g.nodes):
        if n.op is not Op.MUL_MAT or not n.weight_bytes:
            continue
        key = (n.layer, n.block, n.deps)
        groups.setdefault(key, []).append(i)
    out = []
    for (layer, block, _deps), ids in groups.items():
        if len(ids) > 1:
            out.append(ConcurrentSet(layer, block, tuple(ids),
                                     tuple(g.nodes[i].name for i in ids)))
    return sorted(out, key=lambda s: s.node_ids)


@dataclasses.dataclass(frozen=True)
class FusionPlan:
    """Which projection fusions to apply (flows into ModelConfig flags)."""
    fuse_qkv: bool
    fuse_gate_up: bool
    n_fused_sets: int
    nodes_saved: int


def fusion_plan(g: Graph) -> FusionPlan:
    sets = find_concurrent_gemms(g)
    qkv = any(s.block == "attn" and len(s.node_ids) >= 2 for s in sets)
    gu = any(s.block == "ffn" and len(s.node_ids) >= 2 for s in sets)
    saved = sum(len(s.node_ids) - 1 for s in sets)
    return FusionPlan(qkv, gu, len(sets), saved)


# ---------------------------------------------------------------------------
# Execution-version simulator (paper §7.2, Figs 8-10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VersionResult:
    version: str
    step_s: float
    tokens_per_s: float
    n_nodes: int
    detail: str


def simulate_version(cfg: ModelConfig, version: str, *,
                     threads: int = 4, seq: int = 1, kv_len: int = 64,
                     weight_format: str = "f16",
                     batch: int = 1,
                     megastep_k: Optional[int] = None) -> VersionResult:
    """Predict decode throughput for paper versions V0-V3 on the A17.

    - v0: serial schedule, unfused GEMMs (paper baseline, 11.5 tk/s)
    - v1: topological wave schedule — independent GEMMs dispatched
          concurrently (13 tk/s)
    - v2: v1 + tensor-level parallelism inside each GEMM: the wave's
          memory traffic now streams at full multi-core bandwidth (15)
    - v3: v2 but FFN block offloaded to the GPU — every block boundary
          pays a Metal sync (6 tk/s)

    ``megastep_k`` (when set) additionally charges the per-step host
    dispatch cost amortized over a K-token megastep — the same
    dispatch-overhead term that decides the paper's §5 CPU-vs-GPU
    result. ``None`` keeps the paper-calibrated ladder untouched.
    """
    cpu = cm.a17_cpu(threads)
    fused = version in ("v2", "v3")
    g = build_decoder_graph(cfg, seq=seq, kv_len=kv_len, batch=batch,
                            weight_format=weight_format, fused=fused)

    # Calibration notes (EXPERIMENTS.md §Paper-repro): the §7 experiments
    # ran on an instrumented build whose serial baseline (11.5 tk/s)
    # sits below the untouched llama.cpp of Fig 4 (17 tk/s @2t). The
    # version deltas — not the absolute baseline — are the paper's
    # claim, and they fall out of (a) strided-vs-sequential streaming
    # efficiency and (b) barrier count per schedule.
    if version == "v0":
        # serial schedule; intra-op threading partitions each GEMM into
        # strided slices -> poor DRAM row locality (eff 0.66/0.95)
        hw = dataclasses.replace(cpu, mem_efficiency=0.66)
        t = cm.graph_time_serial(g, hw)
        detail = "serial schedule, unfused, strided intra-op threading"
    elif version == "v1":
        # graph-level parallelism: concurrent independent GEMMs, each
        # single-threaded -> sequential streams but imperfect overlap
        t = cm.graph_time_wave(g, cpu, overlap_efficiency=0.78)
        detail = "wave schedule over independent GEMMs"
    elif version == "v2":
        # + tensor parallelism inside fused GEMMs: sequential streaming
        # at aggregate bandwidth, one barrier per wave
        t = cm.graph_time_wave(g, cpu, overlap_efficiency=0.92)
        detail = "wave schedule + intra-GEMM tensor parallelism (fused)"
    elif version == "v3":
        hw = dataclasses.replace(cpu, mem_efficiency=0.92 * 0.95)
        t = cm.graph_time_heterogeneous(g, hw, cm.A17_GPU,
                                        boundary_tags=("ffn",))
        detail = "CPU attention + GPU FFN, per-block Metal sync"
    else:
        raise ValueError(version)
    if megastep_k:
        hw_disp = cm.A17_GPU if version == "v3" else cpu
        t = t + hw_disp.dispatch_overhead_s / megastep_k
        detail += f" + dispatch/{megastep_k}"
    return VersionResult(version, t, cm.tokens_per_second(t, seq * batch),
                         len(g.nodes), detail)


def simulate_megastep(cfg: ModelConfig,
                      hw: Optional[cm.HardwareSpec] = None, *,
                      threads: int = 4, kv_len: int = 64,
                      weight_format: str = "f16", batch: int = 1,
                      ks: Sequence[int] = (1, 4, 8, 16),
                      donate_carries: bool = True,
                      prefill_share: float = 0.0,
                      kernel_backend: str = "pallas",
                      ) -> Dict[int, VersionResult]:
    """Predict serving-loop tok/s as a function of megastep K.

    Per-token device time comes from the v2 (fused wave) schedule; each
    megastep then pays ``hw.dispatch_overhead_s`` once per K tokens —
    the analytic twin of ``benchmarks/serving_bench.py``'s sweep, and
    the napkin math ``core.dispatch.plan`` uses to choose K.

    ``donate_carries=False`` charges the un-donated carry boundary
    (one extra cache-sized write per dispatch — what the engine's
    ``donate_argnums`` removes). ``prefill_share`` models mixed load
    under chunked admission: that fraction of slot-substeps carries
    prompt tokens instead of emitting decode tokens, so reported
    tok/s scales by ``1 - prefill_share`` (the riders themselves add
    no time — same scan, same shapes).

    ``kernel_backend`` selects the dequant execution model for
    quantized ``weight_format``s: the default ``"pallas"`` is the
    fused in-register dequant the graph nodes already encode;
    ``"xla"`` adds the materialized-unpack stream on top
    (:func:`_xla_unpack_penalty_s`) — the PR-4 regime where q4_0
    decoded *slower* than q8_0 despite streaming half the bytes.
    """
    hw = hw or cm.a17_cpu(threads)
    g = build_decoder_graph(cfg, seq=1, kv_len=kv_len, batch=batch,
                            weight_format=weight_format, fused=True)
    per_tok = cm.graph_time_wave(g, hw, overlap_efficiency=0.92) \
        + _xla_unpack_penalty_s(g, weight_format, hw, kernel_backend)
    carry = cm.decode_carry_bytes(cfg, batch, kv_len)
    out = {}
    for k in ks:
        t = cm.megastep_time(per_tok, hw, k, carry_bytes=carry,
                             donate_carries=donate_carries)
        dec_tokens = k * batch * (1.0 - prefill_share)
        out[k] = VersionResult(
            f"megastep_k{k}", t / k, cm.tokens_per_second(t, 1)
            * dec_tokens,
            len(g.nodes),
            f"1 dispatch / {k} tok; per-token device {per_tok*1e6:.0f}us "
            f"+ dispatch {hw.dispatch_overhead_s/k*1e6:.0f}us"
            + ("" if donate_carries else
               f" + carry copy {carry/ (hw.mem_bw*hw.mem_efficiency)/k*1e6:.0f}us"))
    return out


def simulate_precision(cfg: ModelConfig,
                       hw: Optional[cm.HardwareSpec] = None, *,
                       threads: int = 4, kv_len: int = 64,
                       batch: int = 1,
                       formats: Sequence[str] = ("f16", "q8_0", "q4_0"),
                       ks: Sequence[int] = (1, 8),
                       donate_carries: bool = True,
                       kernel_backend: str = "pallas",
                       ) -> Dict[str, Dict[int, VersionResult]]:
    """Serving throughput across weight precisions × megastep K — the
    analytic twin of ``benchmarks/serving_bench.py``'s precision sweep
    (paper §5.3, Fig 4: F16 vs Q8_0 vs Q4_0).

    Each format rebuilds the decode graph with its
    ``bits_per_weight`` / ``dequant_flops_per_weight`` (via
    ``core.precision``), so the prediction carries both the
    memory-roofline win (weight stream shrinks to 8.5/16 or 4.5/16)
    and the dequant tax that erodes it on compute-poor hardware. On a
    memory-bound decode the ordering must come out q4_0 > q8_0 > f16 —
    when a measured backend inverts it (e.g. XLA dequantizing in a
    separate pass instead of in-kernel), that gap is the actionable
    delta, not noise. Pass ``kernel_backend="xla"`` to *predict* that
    inversion instead of just observing it: the materialized-unpack
    tax re-ranks q4_0 below q8_0 (and below f16 on bandwidth-rich
    parts) exactly as the measured sweep does.
    """
    hw = hw or cm.a17_cpu(threads)
    return {fmt: simulate_megastep(
        cfg, hw, kv_len=kv_len, weight_format=fmt, batch=batch, ks=ks,
        donate_carries=donate_carries, kernel_backend=kernel_backend)
        for fmt in formats}


def simulate_kv_precision(cfg: ModelConfig,
                          hw: Optional[cm.HardwareSpec] = None, *,
                          threads: int = 4, batch: int = 1,
                          formats: Sequence[str] = ("bf16", "q8_0",
                                                    "q4_0"),
                          ks: Sequence[int] = (1, 8),
                          kv_lens: Sequence[int] = (64, 1024, 8192),
                          weight_format: str = "f16",
                          donate_carries: bool = True,
                          kernel_backend: str = "pallas",
                          ) -> Dict[str, Dict[int, Dict[int,
                                                        VersionResult]]]:
    """Serving throughput across KV-cache precisions × megastep K ×
    context length — the analytic twin of
    ``benchmarks/serving_bench.py --sweep kv``.

    The cache stream is the one that *grows* with context and batch
    (weights don't), so unlike the weight sweep the win here is a
    function of ``kv_len``: at short context the cache bytes are
    negligible next to the weight stream + dispatch floor, at long
    context they dominate and both quantized formats must beat bf16.
    Whether q4_0 or q8_0 leads is the per-element dequant-tax call
    (Fig 4e erosion): on the compute-poor A17 the q4 unpack cost hands
    the win to q8_0 — the same inversion PR 3 measured for weights on
    XLA-CPU — while compute-rich TPUs keep q4_0 ahead. Quantizing the
    cache also
    shrinks the megastep *carry*, so the un-donated boundary term
    scales by the same ``stream_ratio``. Recurrent families
    (ssm/hybrid) serve bf16 state regardless — ``kv_quant`` is a
    contract no-op there, and this simulator reflects that by not
    rescaling their cache stream.

    ``kernel_backend`` selects the dequant execution model: the
    default ``"pallas"`` reads the quantized cache in-register (the
    fused ``decode_attention_quant`` kernel); ``"xla"`` charges the
    materialized bf16 unpack (``dequantize_rows`` every megastep) on
    the cache *read* stream via ``megastep_time``. The carry term
    keeps the plain ``stream_ratio`` either way — storage crossing
    the dispatch boundary is quantized regardless of who dequantizes.

    Returns ``{fmt: {kv_len: {k: VersionResult}}}``.
    """
    hw = hw or cm.a17_cpu(threads)
    noop = cfg.arch_type in ("ssm", "hybrid")
    # the bf16-calibrated step depends only on kv_len, not the format
    per_ctx = {}
    for kvl in kv_lens:
        g = build_decoder_graph(cfg, seq=1, kv_len=kvl, batch=batch,
                                weight_format=weight_format, fused=True)
        per_ctx[kvl] = (cm.graph_time_wave(g, hw,
                                           overlap_efficiency=0.92)
                        + _xla_unpack_penalty_s(g, weight_format, hw,
                                                kernel_backend),
                        cm.decode_carry_bytes(cfg, batch, kvl),
                        len(g.nodes))
    out: Dict[str, Dict[int, Dict[int, VersionResult]]] = {}
    for fmt in formats:
        eff = "bf16" if noop else fmt
        ratio = (1.0 if eff in ("bf16", "f16", "f32")
                 else get_format(eff).stream_ratio)
        per_len: Dict[int, Dict[int, VersionResult]] = {}
        for kvl in kv_lens:
            per_tok, cache, n_nodes = per_ctx[kvl]
            per_k: Dict[int, VersionResult] = {}
            for k in ks:
                t = cm.megastep_time(
                    per_tok, hw, k, carry_bytes=cache * ratio,
                    donate_carries=donate_carries,
                    cache_bytes=cache, kv_format=eff,
                    kernel_backend=kernel_backend)
                per_k[k] = VersionResult(
                    f"kv_{fmt}_ctx{kvl}_k{k}", t / k,
                    cm.tokens_per_second(t, 1) * k * batch,
                    n_nodes,
                    f"cache {cache * ratio / 1e3:.1f}kB/token "
                    f"({eff}), 1 dispatch / {k} tok")
            per_len[kvl] = per_k
        out[fmt] = per_len
    return out


def simulate_admission(cfg: ModelConfig,
                       hw: Optional[cm.HardwareSpec] = None, *,
                       threads: int = 4, k: int = 8, batch: int = 4,
                       prompt_len: int = 32, max_new: int = 32,
                       kv_len: int = 64, weight_format: str = "f16",
                       prefill_bucket: float = 1.0,
                       donate_carries: bool = True,
                       ) -> Dict[str, VersionResult]:
    """Stall-prefill vs chunked-prefill admission, analytically.

    Steady state, one batch turnover (every slot serves one request of
    ``prompt_len`` prompt + ``max_new`` generated tokens):

    - ``stall``: admission runs as separate prefill dispatches between
      megasteps; *every* slot idles for each one. Wall per turnover =
      ``max_new`` substeps + (batch / prefill_bucket) stalls of
      (dispatch overhead + full-prompt prefill compute).
      ``prefill_bucket`` = requests sharing one length-bucketed
      dispatch (batch → perfect bucketing, 1 → worst case).
    - ``chunked``: prompts ride inside the scan, one token per substep
      — zero extra dispatches, but the riding slot spends
      ``prompt_len`` substeps not decoding. Wall per turnover =
      ``prompt_len + max_new`` substeps.

    Returns decode-phase tok/s per mode (the engine benchmark's
    ``mixed_workload`` metric). Chunked wins when the dispatch/stall
    term outweighs the riding cost — exactly the paper's §5
    fixed-cost-vs-FLOPs tradeoff applied to admission.
    """
    hw = hw or cm.a17_cpu(threads)
    g = build_decoder_graph(cfg, seq=1, kv_len=kv_len, batch=batch,
                            weight_format=weight_format, fused=True)
    per_tok = cm.graph_time_wave(g, hw, overlap_efficiency=0.92)
    carry = cm.decode_carry_bytes(cfg, batch, kv_len)
    substep = cm.megastep_time(per_tok, hw, k, carry_bytes=carry,
                               donate_carries=donate_carries) / k
    gp = build_decoder_graph(cfg, seq=max(prompt_len, 1), kv_len=0,
                             batch=1, weight_format=weight_format,
                             fused=True)
    prefill_t = cm.graph_time_wave(gp, hw, overlap_efficiency=0.92) \
        + hw.dispatch_overhead_s
    dec_tokens = batch * max_new

    stall_wall = max_new * substep + (batch / max(prefill_bucket, 1e-9)) \
        * prefill_t
    chunked_wall = (prompt_len + max_new) * substep
    return {
        "stall": VersionResult(
            "admission_stall", stall_wall,
            cm.tokens_per_second(stall_wall, 1) * dec_tokens, len(g.nodes),
            f"{batch/max(prefill_bucket,1e-9):.1f} prefill stalls x "
            f"{prefill_t*1e6:.0f}us per turnover"),
        "chunked": VersionResult(
            "admission_chunked", chunked_wall,
            cm.tokens_per_second(chunked_wall, 1) * dec_tokens,
            len(g.nodes),
            f"{prompt_len} rider substeps x {substep*1e6:.0f}us, "
            "0 extra dispatches"),
    }


def simulate_paging(cfg: ModelConfig,
                    hw: Optional[cm.HardwareSpec] = None, *,
                    threads: int = 4, slots: int = 4, k: int = 8,
                    prompt_len: int = 32, max_new: int = 32,
                    kv_len: int = 64,
                    page_sizes: Sequence[int] = (8, 16, 32),
                    hit_rate: float = 0.0,
                    shared_fraction: float = 0.75,
                    live_tokens: Optional[float] = None,
                    weight_format: str = "f16",
                    kv_quant: str = "bf16",
                    donate_carries: bool = True,
                    kernel_backend: str = "pallas",
                    ) -> Dict[int, Dict]:
    """Dense vs paged KV cache, analytically — the twin of
    ``serving_bench --sweep paging`` and the model behind
    ``dispatch.plan``'s page-size knob.

    Three effects move per page size ``P``:

    - **footprint**: the dense engine preallocates
      ``slots x kv_len`` rows; the paged pool holds
      ``live_tokens + slots x P/2`` rows (tail-page fragmentation)
      plus table/garbage-block overhead
      (:func:`cost_model.paged_cache_bytes`) — *this* is the term
      that scales with live tokens instead of provisioned capacity.
    - **gather tax**: every substep materializes a dense view of the
      live cache through the block table (~2 extra passes over the
      live cache stream), charged via ``megastep_time``'s
      ``page_gather_bytes`` — grows with context, shrinks per-page-
      size only via table locality (not modelled; P-independent).
    - **prefix reuse**: under chunked admission a prefix hit maps
      ``hit_rate x shared_fraction x prompt_len`` already-cached
      tokens copy-on-write into the new slot's table, so those rider
      substeps vanish from the turnover wall (the Xiao et al. mobile
      traffic argument: bursty requests share system-prompt
      prefixes). Sharable tokens round *down* to whole pages, so
      small P captures more of the prefix.

    Recurrent/windowed families serve dense state regardless
    (``Model.paging_effective`` contract no-op) — every paged entry
    degenerates to the dense result there.

    Returns ``{page_size: {"step": VersionResult, "pool_bytes": ...,
    "dense_bytes": ..., "bytes_per_live_token": ...,
    "rider_substeps_saved": ...}}`` with page size 0 = the dense
    baseline.
    """
    hw = hw or cm.a17_cpu(threads)
    # mirror Model.paging_effective: recurrent state and windowed
    # rings (explicit sliding_window or the long-context fallback)
    # stay dense
    win = (0 if cfg.arch_type in ("ssm", "hybrid")
           else cfg.sliding_window
           or (cfg.window_long_ctx if kv_len > cfg.max_full_attn
               else 0))
    noop = cfg.arch_type in ("ssm", "hybrid") or bool(win)
    g = build_decoder_graph(cfg, seq=1, kv_len=kv_len, batch=slots,
                            weight_format=weight_format, fused=True)
    per_tok = cm.graph_time_wave(g, hw, overlap_efficiency=0.92) \
        + _xla_unpack_penalty_s(g, weight_format, hw, kernel_backend)
    eff_kv = "bf16" if noop else kv_quant
    ratio = (1.0 if eff_kv in ("bf16", "f16", "f32")
             else get_format(eff_kv).stream_ratio)
    dense_bytes = cm.decode_carry_bytes(cfg, slots, kv_len) * ratio
    bytes_per_token = dense_bytes / max(slots * kv_len, 1)
    if live_tokens is None:
        # steady state: each slot holds its prompt plus half its
        # decode budget on average
        live_tokens = slots * min(prompt_len + max_new / 2.0, kv_len)
    dec_tokens = slots * max_new

    out: Dict[int, Dict] = {}
    for p in (0,) + tuple(page_sizes):
        paged = bool(p) and not noop
        gather = 2.0 * live_tokens * bytes_per_token / max(slots * k, 1) \
            if paged else 0.0
        substep = cm.megastep_time(
            per_tok, hw, k, carry_bytes=dense_bytes,
            donate_carries=donate_carries, kv_format=eff_kv,
            cache_bytes=dense_bytes, kernel_backend=kernel_backend,
            page_gather_bytes=gather) / k
        # chunked turnover: prefix hits drop whole shared pages of
        # rider substeps (floor to pages; >= 1 token always fed)
        shared_tok = 0.0
        if paged and hit_rate > 0.0:
            pages = int(min(shared_fraction * prompt_len,
                            prompt_len - 1) // p)
            shared_tok = hit_rate * pages * p
        wall = (prompt_len - shared_tok + max_new) * substep
        pool = (cm.paged_cache_bytes(
                    live_tokens, p, bytes_per_token=bytes_per_token,
                    active_slots=slots, max_pages=-(-kv_len // p))
                if paged else dense_bytes)
        out[p] = {
            "step": VersionResult(
                f"paging_p{p}" if paged else "paging_dense", wall,
                cm.tokens_per_second(wall, 1) * dec_tokens,
                len(g.nodes),
                (f"pool {pool/1e3:.1f}kB vs dense "
                 f"{dense_bytes/1e3:.1f}kB; "
                 f"{shared_tok:.1f} rider substeps saved/turnover"
                 if paged else
                 f"dense prealloc {dense_bytes/1e3:.1f}kB")),
            "pool_bytes": pool,
            "dense_bytes": dense_bytes,
            "bytes_per_live_token": pool / max(live_tokens, 1.0),
            "rider_substeps_saved": shared_tok,
        }
    return out


def simulate_async_overlap(cfg: ModelConfig,
                           hw: Optional[cm.HardwareSpec] = None, *,
                           threads: int = 4, kv_len: int = 64,
                           weight_format: str = "f16", batch: int = 1,
                           k: int = 8,
                           host_drain_per_token_s: float = 8e-6,
                           depths: Sequence[int] = (1, 2),
                           donate_carries: bool = True,
                           kernel_backend: str = "pallas",
                           ) -> Dict[int, VersionResult]:
    """Serial vs double-buffered serving loop, analytically.

    The host pays a per-megastep gap — draining the packed token block
    (device→host transfer + per-token Python bookkeeping) and staging
    the next admission arrays — modelled as
    ``host_drain_per_token_s * k * batch``. At ``pipeline_depth=1``
    that gap sits between device megasteps; at depth >= 2 dispatch is
    async under JAX, so draining megastep N overlaps the device
    running N+1 and the gap is hidden up to the device-step time
    (:func:`cost_model.megastep_time`'s overlap term). The predicted
    win saturates at ``host / (device + host)`` of the serial wall —
    on a device-bound loop the drain hides completely; on a
    host-bound loop the device starves instead and depth stops
    helping. The analytic twin of ``serving_bench --sweep async``.
    """
    hw = hw or cm.a17_cpu(threads)
    g = build_decoder_graph(cfg, seq=1, kv_len=kv_len, batch=batch,
                            weight_format=weight_format, fused=True)
    per_tok = cm.graph_time_wave(g, hw, overlap_efficiency=0.92) \
        + _xla_unpack_penalty_s(g, weight_format, hw, kernel_backend)
    carry = cm.decode_carry_bytes(cfg, batch, kv_len)
    host = host_drain_per_token_s * k * batch
    boundary = 0.0 if donate_carries else \
        carry / (hw.mem_bw * hw.mem_efficiency)
    device = boundary + k * per_tok
    out = {}
    for d in depths:
        t = cm.megastep_time(per_tok, hw, k, carry_bytes=carry,
                             donate_carries=donate_carries,
                             host_drain_s=host, pipeline_depth=d)
        out[d] = VersionResult(
            f"pipeline_depth{d}", t / k,
            cm.tokens_per_second(t, 1) * k * batch, len(g.nodes),
            f"device {device*1e6:.0f}us + host drain {host*1e6:.0f}us "
            + ("serial" if d < 2 else
               f"overlapped (hidden {min(host, device)*1e6:.0f}us)")
            + f" + dispatch {hw.dispatch_overhead_s*1e6:.0f}us")
    return out


def simulate_overload(cfg: ModelConfig,
                      hw: Optional[cm.HardwareSpec] = None, *,
                      threads: int = 4, slots: int = 4, k: int = 8,
                      prompt_len: int = 32, max_new: int = 32,
                      page_size: int = 8, cache_blocks: int = 0,
                      arrival_multiples: Sequence[float] = (0.5, 1.0,
                                                           2.0, 3.0),
                      deadline_factor: float = 3.0,
                      horizon_s: Optional[float] = None,
                      weight_format: str = "f16",
                      donate_carries: bool = True,
                      kernel_backend: str = "pallas",
                      ) -> Dict[str, Dict]:
    """Overload behavior of a bounded vs unbounded admission queue,
    analytically — the twin of ``serving_bench --sweep overload`` and
    the model behind ``dispatch.plan``'s queue-bound knob.

    Capacity first: a request occupies a slot for
    ``prompt_len + max_new`` chunked substeps (prompt rides in-scan),
    and the block pool caps concurrency at
    ``(cache_blocks - 1) // pages_per_request`` slots — whichever is
    smaller sets the service rate ``mu`` (requests/s). Then, per
    arrival rate ``lambda = m * mu``:

    - **bounded queue + EDF + preemption** sheds the excess at
      admission: shed fraction ``max(0, (lambda - mu) / lambda)``,
      queue wait stays ~bounded (``queue_bound / mu``), so admitted
      requests hit a deadline of ``deadline_factor x`` their service
      time as long as the bound is modest — goodput
      ``min(lambda, mu) * max_new * hit`` tok/s holds flat past
      saturation. Preemption rate ~= the pool-starved fraction of
      admissions (arrivals finding all block-budgeted slots busy while
      extra slots idle).
    - **unbounded queue** sheds nothing but its backlog grows
      ``(lambda - mu) * t``; by the end of a ``horizon_s`` window
      (default: 10x the deadline) the queue wait crosses any fixed
      deadline, so only requests arriving in the first
      ``t* = (D - service) * mu / (lambda - mu)`` seconds (D = the
      deadline) finish in time — goodput *decays* with the horizon
      instead of holding. That's the measured cliff the bench shows
      and the reason ``plan`` emits a queue bound at all.

    Returns ``{"capacity": {...}, "sweep": {multiple: {"bounded":
    {...}, "unbounded": {...}}}}`` with shed/preempt/goodput/hit-rate
    entries per point.
    """
    hw = hw or cm.a17_cpu(threads)
    g = build_decoder_graph(cfg, seq=1, kv_len=prompt_len + max_new,
                            batch=slots, weight_format=weight_format,
                            fused=True)
    per_tok = cm.graph_time_wave(g, hw, overlap_efficiency=0.92) \
        + _xla_unpack_penalty_s(g, weight_format, hw, kernel_backend)
    carry = cm.decode_carry_bytes(cfg, slots, prompt_len + max_new)
    substep = cm.megastep_time(per_tok, hw, k, carry_bytes=carry,
                               donate_carries=donate_carries,
                               kernel_backend=kernel_backend) / k
    service_s = (prompt_len + max_new) * substep
    pages_per_req = -(-(prompt_len + max_new) // max(page_size, 1))
    pool_slots = ((cache_blocks - 1) // pages_per_req
                  if cache_blocks else slots)
    max_live = max(1, min(slots, pool_slots))
    mu = max_live / service_s                      # requests/s
    queue_bound = 2 * slots
    deadline_s = deadline_factor * service_s
    if horizon_s is None:
        horizon_s = 10.0 * deadline_s
    # pool-starved admissions preempt: the fraction of slot capacity
    # the block pool can't back (idle slots an arrival would claim if
    # a victim's blocks were recycled)
    preempt_frac = (max(0.0, (min(slots, queue_bound) - max_live)
                        / float(slots)) if cache_blocks else 0.0)

    sweep: Dict[float, Dict] = {}
    for m in arrival_multiples:
        lam = m * mu
        over = max(0.0, lam - mu)
        # bounded: shed keeps the queue at its bound; an admitted
        # request waits its mean queue position (~half the bound)
        # draining at mu
        shed = over / lam if lam > 0 else 0.0
        wait_b = (0.5 * queue_bound / mu) if over > 0 else \
            (0.5 * min(lam, mu) / mu) * service_s
        hit_b = 1.0 if wait_b + service_s <= deadline_s else max(
            0.0, 1.0 - (wait_b + service_s - deadline_s) / deadline_s)
        good_b = min(lam, mu) * max_new * hit_b
        # unbounded: nothing shed, backlog grows over * t; a request
        # arriving at t waits over * t / mu — past t* it misses D
        if over > 0:
            slack = max(deadline_s - service_s, 0.0)
            t_star = slack * mu / over
            hit_u = min(1.0, max(0.0, t_star / horizon_s))
        else:
            hit_u = hit_b
        good_u = min(lam, mu) * max_new * hit_u
        sweep[m] = {
            "arrival_rps": lam,
            "bounded": {"shed_rate": shed,
                        "preempt_rate": (1.0 - shed) * preempt_frac,
                        "deadline_hit_rate": hit_b,
                        "goodput_tok_s": good_b},
            "unbounded": {"shed_rate": 0.0,
                          "preempt_rate": 0.0,
                          "deadline_hit_rate": hit_u,
                          "goodput_tok_s": good_u},
        }
    return {
        "capacity": {
            "service_s_per_request": service_s,
            "drain_s_per_request": 1.0 / mu,
            "max_live_requests": max_live,
            "pages_per_request": pages_per_req,
            "capacity_rps": mu,
            "queue_bound": queue_bound,
            "deadline_s": deadline_s,
        },
        "sweep": sweep,
    }


def backend_throughput(cfg: ModelConfig, backend: str, *,
                       threads: int = 2, weight_format: str = "f16",
                       kv_len: int = 64, seq: int = 1,
                       batch: int = 1) -> float:
    """Tokens/s for the paper's Fig 4 sweep (GPU vs 1-6 CPU threads)."""
    g = build_decoder_graph(cfg, seq=seq, kv_len=kv_len, batch=batch,
                            weight_format=weight_format, fused=False)
    if backend == "gpu":
        t = cm.graph_time_serial(g, cm.A17_GPU)
    elif backend == "cpu":
        t = cm.graph_time_wave(g, cm.a17_cpu(threads))
    else:
        raise ValueError(backend)
    return cm.tokens_per_second(t, seq * batch)
