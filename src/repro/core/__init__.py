"""repro.core — the paper's contribution layer.

- graph: ggml-style compute-graph IR with FLOP/byte accounting
- scheduler: topological graph-level parallelism (paper §7, V0-V3)
- cost_model: A17 Pro + TPU v5e hardware models, roofline terms
- profiler: op-class time attribution (paper §6, Figs 5/6)
- dispatch: hardware-aware execution planner (paper §7.5)
- precision: F16/Q8_0/Q4_0 format descriptors
"""
from repro.core.graph import Graph, Node, Op, build_decoder_graph
from repro.core.scheduler import (
    find_concurrent_gemms, fusion_plan, simulate_version,
    simulate_megastep, simulate_admission, simulate_precision,
    simulate_async_overlap, simulate_paging, simulate_overload,
    simulate_kv_precision, backend_throughput,
)
from repro.core.cost_model import (
    HardwareSpec, TPU_V5E, A17_GPU, a17_cpu, roofline, RooflineTerms,
    model_flops, megastep_time, megastep_tokens_per_s,
    decode_carry_bytes, quantized_per_token_s,
)
from repro.core.profiler import profile_graph, profile_phases
from repro.core.dispatch import plan, ExecutionPlan, choose_megastep_k
from repro.core.precision import get_format, PrecisionFormat

__all__ = [
    "Graph", "Node", "Op", "build_decoder_graph",
    "find_concurrent_gemms", "fusion_plan", "simulate_version",
    "simulate_megastep", "simulate_admission", "simulate_precision",
    "simulate_async_overlap", "simulate_paging", "simulate_overload",
    "simulate_kv_precision", "backend_throughput",
    "HardwareSpec", "TPU_V5E", "A17_GPU", "a17_cpu", "roofline",
    "RooflineTerms", "model_flops", "megastep_time",
    "megastep_tokens_per_s", "decode_carry_bytes",
    "quantized_per_token_s",
    "profile_graph", "profile_phases",
    "plan", "ExecutionPlan", "choose_megastep_k",
    "get_format", "PrecisionFormat",
]
