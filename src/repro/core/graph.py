"""Compute-graph IR — the llama.cpp/ggml graph analogue (paper §3).

The paper analyzes llama.cpp's ``ggml_cgraph``: nodes are primitive ops
(MUL_MAT, ADD, RMS_NORM, ROPE, SOFT_MAX, ...) executed in a serial
schedule. We rebuild that graph symbolically, with per-node FLOP and
byte counts, so the scheduler (§7 topological parallelism), the
profiler (§6 op breakdown) and the cost model (Fig 4 throughput) can
all reason about it without running anything.

``build_decoder_graph`` follows the paper's Algorithm 1 (build_llama):
per layer — norm → {Q,K,V} matmuls → rope → attention → out-proj →
residual add → ffn-norm → {gate,up} matmuls → glu-mul → down matmul →
residual add; then final norm + lm_head.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.precision import PrecisionFormat, get_format


class Op(enum.Enum):
    # names mirror GGML op names used in the paper's Fig. 5
    MUL_MAT = "MUL_MAT"
    ADD = "ADD"
    MUL = "MUL"            # elementwise (GLU gating, scaling)
    RMS_NORM = "RMS_NORM"
    ROPE = "ROPE"
    SOFT_MAX = "SOFT_MAX"
    GET_ROWS = "GET_ROWS"  # embedding lookup
    UNARY = "UNARY"        # silu / gelu
    CPY = "CPY"            # kv-cache write / layout change
    SCAN = "SCAN"          # ssm / lru recurrence (non-ggml extension)
    TOPK = "TOPK"          # router (non-ggml extension)


@dataclasses.dataclass
class Node:
    name: str
    op: Op
    flops: float
    # bytes read/written, split so quantization applies to weights only
    weight_bytes: float
    act_bytes: float
    deps: Tuple[int, ...] = ()
    # tag: which block this node belongs to ("attn", "ffn", "other") and
    # which named matmul it is (paper Fig 6: Qcur, Kcur, Vcur, kqv_out,
    # ffn_up, ffn_gate, ffn_down)
    block: str = "other"
    tag: str = ""
    layer: int = -1

    @property
    def bytes(self) -> float:
        return self.weight_bytes + self.act_bytes


@dataclasses.dataclass
class Graph:
    name: str
    nodes: List[Node]

    def __iter__(self):
        return iter(self.nodes)

    def __len__(self):
        return len(self.nodes)

    @property
    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    @property
    def total_bytes(self) -> float:
        return sum(n.bytes for n in self.nodes)

    def by_op(self) -> Dict[str, List[Node]]:
        out: Dict[str, List[Node]] = {}
        for n in self.nodes:
            out.setdefault(n.op.value, []).append(n)
        return out

    def matmuls_by_tag(self) -> Dict[str, List[Node]]:
        out: Dict[str, List[Node]] = {}
        for n in self.nodes:
            if n.op is Op.MUL_MAT and n.tag:
                out.setdefault(n.tag, []).append(n)
        return out

    # ---- topological wave schedule (paper §7.1) -----------------------
    def waves(self) -> List[List[int]]:
        """Group node indices into dependency levels.

        Nodes in the same wave have no mutual dependencies and may be
        dispatched concurrently — the paper's graph-level parallelism.
        """
        level: List[int] = [0] * len(self.nodes)
        for i, n in enumerate(self.nodes):
            level[i] = 1 + max((level[d] for d in n.deps), default=-1)
        waves: Dict[int, List[int]] = {}
        for i, lv in enumerate(level):
            waves.setdefault(lv, []).append(i)
        return [waves[k] for k in sorted(waves)]


def _mm(name: str, m: int, k: int, n: int, fmt: PrecisionFormat,
        act_bytes_in: float, deps, block: str, tag: str, layer: int,
        act_elt_bytes: int = 2) -> Node:
    """Matmul node: (m,k) @ (k,n); weights are the (k,n) operand."""
    flops = 2.0 * m * k * n + fmt.dequant_flops_per_weight * k * n
    weight_bytes = k * n * fmt.bytes_per_weight
    act_bytes = (m * k + m * n) * act_elt_bytes
    return Node(name, Op.MUL_MAT, flops, weight_bytes, act_bytes,
                tuple(deps), block, tag, layer)


def _ew(name: str, op: Op, elems: float, deps, block: str, layer: int,
        reads: int = 2, writes: int = 1, flops_per_elem: float = 1.0,
        elt_bytes: int = 2) -> Node:
    return Node(name, op, flops_per_elem * elems,
                0.0, (reads + writes) * elems * elt_bytes,
                tuple(deps), block, "", layer)


def build_decoder_graph(cfg: ModelConfig, *, seq: int, kv_len: int = 0,
                        batch: int = 1,
                        weight_format: Optional[str] = None,
                        fused: Optional[bool] = None) -> Graph:
    """Build the ggml-style compute graph for one forward pass.

    ``seq`` is the number of new tokens (prefill: prompt length;
    decode: 1). ``kv_len`` is the pre-existing KV-cache length.
    ``fused`` overrides cfg.fuse_qkv/fuse_gate_up (used by the
    scheduler-version benchmarks: V0 unfused vs V1+ fused).
    """
    fmt = get_format(weight_format or
                     ("f16" if cfg.quant_policy == "bf16" else cfg.quant_policy))
    act_fmt = get_format("f16")
    fuse_qkv = cfg.fuse_qkv if fused is None else fused
    fuse_gu = (cfg.fuse_gate_up if fused is None else fused) and cfg.glu

    D = cfg.d_model
    T = seq * batch           # new tokens
    total_kv = kv_len + seq
    nodes: List[Node] = []

    def add(node: Node) -> int:
        nodes.append(node)
        return len(nodes) - 1

    inp = add(Node("inp_embd", Op.GET_ROWS, T * D,
                   T * D * fmt.bytes_per_weight, T * D * 2, (), "other",
                   "", -1))

    pattern = cfg.layer_pattern()
    for li, kind in enumerate(pattern):
        if kind == "ssm":
            inp = _ssm_layer(cfg, nodes, add, inp, li, T, fmt)
            continue
        if kind == "rglru":
            inp = _rglru_layer(cfg, nodes, add, inp, li, T, fmt)
            continue
        # ---- attention block (Algorithm 1 lines 4-8) ------------------
        norm = add(_ew(f"l{li}.attn_norm", Op.RMS_NORM, T * D, (inp,),
                       "attn", li, reads=1, flops_per_elem=4))
        qd, kvd = cfg.q_dim, cfg.kv_dim
        if fuse_qkv:
            qkv = add(_mm(f"l{li}.wqkv", T, D, qd + 2 * kvd, fmt,
                          0, (norm,), "attn", "Qcur", li))
            q = k = v = qkv
        else:
            q = add(_mm(f"l{li}.Qcur", T, D, qd, fmt, 0, (norm,),
                        "attn", "Qcur", li))
            k = add(_mm(f"l{li}.Kcur", T, D, kvd, fmt, 0, (norm,),
                        "attn", "Kcur", li))
            v = add(_mm(f"l{li}.Vcur", T, D, kvd, fmt, 0, (norm,),
                        "attn", "Vcur", li))
        rope = add(_ew(f"l{li}.rope", Op.ROPE, T * (qd + kvd), (q, k),
                       "attn", li, flops_per_elem=6))
        kvcpy = add(_ew(f"l{li}.kv_store", Op.CPY, T * 2 * kvd, (rope, v),
                        "attn", li, reads=1))
        # attention scores + weighted sum; window caps effective kv
        window = cfg.sliding_window or (cfg.local_attn_window
                                        if cfg.arch_type == "hybrid" else 0)
        eff_kv = min(total_kv, window) if window else total_kv
        # scores: (heads, T, hd) @ (heads, hd, kv) — activation matmul
        h, hd = cfg.num_heads, cfg.head_dim
        att_flops = 2.0 * batch * h * seq * eff_kv * hd * 2  # qk + av
        att_bytes = batch * (2 * cfg.num_kv_heads * eff_kv * hd  # K,V read
                             + h * seq * eff_kv                  # scores
                             + 2 * h * seq * hd) * 2
        score = add(Node(f"l{li}.kq", Op.MUL_MAT, att_flops / 2, 0,
                         att_bytes / 2, (rope, kvcpy), "attn", "kq", li))
        smax = add(_ew(f"l{li}.soft_max", Op.SOFT_MAX,
                       batch * h * seq * eff_kv, (score,), "attn", li,
                       reads=1, flops_per_elem=5))
        kqv = add(Node(f"l{li}.kqv", Op.MUL_MAT, att_flops / 2, 0,
                       att_bytes / 2, (smax, kvcpy), "attn", "kqv", li))
        attn_out = add(_mm(f"l{li}.kqv_out", T, qd, D, fmt, 0, (kqv,),
                           "attn", "kqv_out", li))
        ffn_inp = add(_ew(f"l{li}.ffn_inp", Op.ADD, T * D,
                          (attn_out, inp), "attn", li))
        # ---- FFN block (Algorithm 1 lines 9-11) -----------------------
        inp = _ffn_block(cfg, nodes, add, ffn_inp, li, T, fmt, fuse_gu)

    fn = add(_ew("final_norm", Op.RMS_NORM, T * D, (inp,), "other", -1,
                 reads=1, flops_per_elem=4))
    add(_mm("lm_head", T, D, cfg.vocab_size, fmt, 0, (fn,), "other",
            "lm_head", -1))
    return Graph(f"{cfg.name}@{fmt.name}", nodes)


def _ffn_block(cfg, nodes, add, ffn_inp, li, T, fmt, fuse_gu) -> int:
    D, F = cfg.d_model, cfg.d_ff
    norm = add(_ew(f"l{li}.ffn_norm", Op.RMS_NORM, T * D, (ffn_inp,),
                   "ffn", li, reads=1, flops_per_elem=4))
    if cfg.is_moe:
        # router + top-k dispatch; experts_per_token experts per token
        rt = add(_mm(f"l{li}.router", T, D, cfg.num_experts, fmt, 0,
                     (norm,), "ffn", "router", li))
        tk = add(_ew(f"l{li}.topk", Op.TOPK, T * cfg.num_experts, (rt,),
                     "ffn", li, reads=1))
        k = cfg.experts_per_token + cfg.num_shared_experts
        Teff = T * k
        deps = (tk,)
    else:
        Teff = T
        deps = (norm,)
    if cfg.glu:
        if fuse_gu:
            gu = add(_mm(f"l{li}.ffn_gate_up", Teff, D, 2 * F, fmt, 0,
                         deps, "ffn", "ffn_up", li))
            pre = [gu]
        else:
            g = add(_mm(f"l{li}.ffn_gate", Teff, D, F, fmt, 0, deps,
                        "ffn", "ffn_gate", li))
            u = add(_mm(f"l{li}.ffn_up", Teff, D, F, fmt, 0, deps,
                        "ffn", "ffn_up", li))
            pre = [g, u]
        act = add(_ew(f"l{li}.glu", Op.MUL, Teff * F, tuple(pre), "ffn",
                      li, flops_per_elem=5))
    else:
        u = add(_mm(f"l{li}.ffn_up", Teff, D, F, fmt, 0, deps, "ffn",
                    "ffn_up", li))
        act = add(_ew(f"l{li}.act", Op.UNARY, Teff * F, (u,), "ffn", li,
                      reads=1, flops_per_elem=4))
    down = add(_mm(f"l{li}.ffn_down", Teff, F, D, fmt, 0, (act,), "ffn",
                   "ffn_down", li))
    return add(_ew(f"l{li}.l_out", Op.ADD, T * D, (down, ffn_inp), "ffn",
                   li))


def _ssm_layer(cfg, nodes, add, inp, li, T, fmt) -> int:
    """Mamba-2 SSD layer: in_proj → conv/scan → out_proj."""
    D, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    norm = add(_ew(f"l{li}.norm", Op.RMS_NORM, T * D, (inp,), "attn", li,
                   reads=1, flops_per_elem=4))
    proj_out = 2 * di + 2 * N + nh
    zxbcdt = add(_mm(f"l{li}.in_proj", T, D, proj_out, fmt, 0, (norm,),
                     "attn", "Qcur", li))
    # chunked SSD scan: intra-chunk quadratic + state update
    C = cfg.ssm_chunk
    nchunks = max(1, T // C)
    scan_flops = (2 * T * C * nh * cfg.ssm_head_dim        # intra-chunk
                  + 4 * T * N * di)                        # state in/out
    scan = add(Node(f"l{li}.ssd_scan", Op.SCAN, scan_flops, 0,
                    (T * di * 4 + nchunks * nh * cfg.ssm_head_dim * N * 2) * 2,
                    (zxbcdt,), "attn", "", li))
    out = add(_mm(f"l{li}.out_proj", T, di, D, fmt, 0, (scan,), "ffn",
                  "ffn_down", li))
    return add(_ew(f"l{li}.l_out", Op.ADD, T * D, (out, inp), "ffn", li))


def _rglru_layer(cfg, nodes, add, inp, li, T, fmt) -> int:
    """RecurrentGemma RG-LRU block + its FFN."""
    D = cfg.d_model
    w = cfg.rglru_width or D
    norm = add(_ew(f"l{li}.norm", Op.RMS_NORM, T * D, (inp,), "attn", li,
                   reads=1, flops_per_elem=4))
    gates = add(_mm(f"l{li}.lru_in", T, D, 2 * w, fmt, 0, (norm,),
                    "attn", "Qcur", li))
    scan = add(Node(f"l{li}.rglru_scan", Op.SCAN, 10.0 * T * w, 0,
                    T * w * 6, (gates,), "attn", "", li))
    out = add(_mm(f"l{li}.lru_out", T, w, D, fmt, 0, (scan,), "attn",
                  "kqv_out", li))
    res = add(_ew(f"l{li}.res", Op.ADD, T * D, (out, inp), "attn", li))
    return _ffn_block(cfg, nodes, add, res, li, T, fmt, cfg.fuse_gate_up)
