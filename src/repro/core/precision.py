"""Precision / quantization format descriptors (paper §4.2, §5.3).

Mirrors llama.cpp's formats: F16 baseline, Q8_0 and Q4_0 group-quants.
``bits_per_weight`` includes the per-group scale overhead — Q4_0 with
group 32 and an f16 scale is 4 + 16/32 = 4.5 bits/weight, exactly the
paper's footnote 1 ("effective 4.5 bits/weight").
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PrecisionFormat:
    name: str
    weight_bits: int          # payload bits per weight
    group_size: int           # weights per scale group (0 → none)
    scale_bits: int           # bits per group scale
    dequant_flops_per_weight: float  # extra in-kernel work
    # Extra HBM bytes per weight the *XLA* backend pays to materialize
    # a bf16 view before the consuming matmul/attention (write + read
    # of the unpacked value). The fused Pallas backend dequantizes
    # in-register and pays 0. q8_0 converts lane-for-lane (XLA fuses
    # the int8→bf16 widen into the dot read), q4_0 cannot — the
    # nibble-unpack forces a materialized bf16 copy: 2 bytes written +
    # 2 re-read. This is the measured PR-4 "dequant tax" that made
    # q4_0 KV decode at 0.75-0.81x bf16 despite streaming 0.281x the
    # bytes.
    xla_unpack_bytes_per_weight: float = 0.0

    @property
    def bits_per_weight(self) -> float:
        if not self.group_size:
            return float(self.weight_bits)
        return self.weight_bits + self.scale_bits / self.group_size

    @property
    def bytes_per_weight(self) -> float:
        return self.bits_per_weight / 8.0

    @property
    def stream_ratio(self) -> float:
        """Weight-stream bytes relative to the bf16/f16 baseline —
        the §5.3 memory-roofline lever (q8_0 → 8.5/16, q4_0 → 4.5/16).
        This is the factor ``cost_model`` applies to the weight share
        of a decode step's bytes when predicting a quantized serving
        configuration from a bf16-calibrated one."""
        return self.bits_per_weight / 16.0

    def effective_stream_ratio(self, kernel_backend: str = "pallas"
                               ) -> float:
        """Stream ratio as the chosen kernel backend actually pays it.

        ``"pallas"`` (fused in-register dequant) streams the quantized
        bytes and nothing else — the ideal :attr:`stream_ratio`.
        ``"xla"`` additionally writes+reads any materialized unpack
        bytes (:attr:`xla_unpack_bytes_per_weight`), which is why a
        4.5-bit format can *lose* to bf16 under XLA while winning
        under the fused kernel — the q4-vs-q8 ordering flip
        ``dispatch.plan`` predicts."""
        if kernel_backend not in ("pallas", "xla"):
            raise ValueError(
                f"kernel_backend must be 'pallas' or 'xla', got "
                f"{kernel_backend!r}")
        extra = (self.xla_unpack_bytes_per_weight / 2.0
                 if kernel_backend == "xla" else 0.0)
        return self.stream_ratio + extra


F32 = PrecisionFormat("f32", 32, 0, 0, 0.0)
F16 = PrecisionFormat("f16", 16, 0, 0, 0.0)
BF16 = PrecisionFormat("bf16", 16, 0, 0, 0.0)
Q8_0 = PrecisionFormat("q8_0", 8, 32, 16, 1.5)   # widen int8 + scale
Q4_0 = PrecisionFormat("q4_0", 4, 32, 16, 4.0,   # mask/shift/sign-extend
                       xla_unpack_bytes_per_weight=4.0)
#   dequant cost: NEON q4 path is ~3-4 extra ops per weight (nibble
#   mask, shift, sign-extend, scale) — this is why the CPU's Q4 win
#   shrinks as models grow and the GPU retakes the lead at 7B (Fig 4e).

FORMATS = {f.name: f for f in (F32, F16, BF16, Q8_0, Q4_0)}


def get_format(name: str) -> PrecisionFormat:
    return FORMATS[name]
