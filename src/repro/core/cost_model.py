"""Hardware descriptors + analytic cost/roofline model.

Two uses:

1. **Paper-faithful reproduction** — model the iPhone 15 Pro (A17 Pro
   CPU, Apple GPU) well enough that the paper's Fig 4/8-10 numbers come
   out of the analysis (17 vs 12.8 tk/s; the 11.5→13→15→6 version
   ladder). Constants are calibrated from public A17 Pro specs
   (LPDDR5X ≈ 51.2 GB/s, P-core NEON fp16 ≈ 102 GFLOP/s) and the
   paper's own measurements; EXPERIMENTS.md reports predicted vs
   measured.

2. **TPU roofline** (deliverable g) — the three-term roofline for the
   compiled dry-runs: compute, memory, collective seconds per step.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.graph import Graph, Node, Op
from repro.core.precision import get_format


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # FLOP/s at the spec's native precision
    mem_bw: float              # B/s achievable HBM/DRAM bandwidth
    link_bw: float = 0.0       # B/s per inter-chip link (TPU ICI)
    hbm_bytes: float = 0.0
    # dispatch model (paper C3/C4): fixed cost to launch one graph node
    node_overhead_s: float = 0.0
    # cross-device synchronization cost (paper V3: CPU<->GPU boundary)
    sync_overhead_s: float = 0.0
    # fixed cost to launch one *whole decode step* from the host
    # (Python→runtime dispatch + host sync to read the result). This is
    # the term a K-token megastep amortizes: one launch per K tokens.
    dispatch_overhead_s: float = 0.0
    mem_efficiency: float = 1.0   # achieved/peak bandwidth
    flop_efficiency: float = 1.0
    # effective rate for non-GEMM elementwise/transcendental ops
    # (rope/softmax/silu run scalar libm on mobile: ~0.25 GFLOP/s/thread;
    # this is what makes the paper's non-matmul share ~12-24%)
    ew_flops: float = 0.0         # 0 → use peak_flops * flop_efficiency

    @property
    def ridge_flops_per_byte(self) -> float:
        return (self.peak_flops * self.flop_efficiency) / (
            self.mem_bw * self.mem_efficiency)


# ---------------------------------------------------------------------------
# TPU v5e (the deployment target; constants from the brief)
# ---------------------------------------------------------------------------
TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,        # bf16
    mem_bw=819e9,
    link_bw=50e9,             # per ICI link
    hbm_bytes=16 * 2**30,
    node_overhead_s=0.0,      # XLA fuses; no per-node dispatch cost
    dispatch_overhead_s=75e-6,  # Python→XLA launch + result sync
    mem_efficiency=1.0,       # roofline terms reported at peak
    flop_efficiency=1.0,
)

# ---------------------------------------------------------------------------
# iPhone 15 Pro — A17 Pro (paper §4.1): 2P+4E CPU, LPDDR5 ~51.2 GB/s
# ---------------------------------------------------------------------------
# Per-core sustainable stream bandwidth: a single P-core cannot saturate
# the memory controller; ~32 GB/s P-core, ~12 GB/s E-core (public A17
# memory studies). fp16 NEON: P-core ~3.8 GHz * 2 FMA pipes * 8 lanes
# * 2 = ~120 GFLOP/s; E-core ~1/4. Elementwise transcendentals
# (rope/softmax exp, silu) run near-scalar: ~0.25 GFLOP/s/thread.
A17_PCORE_BW = 32e9
A17_ECORE_BW = 12e9
A17_PEAK_BW = 51.2e9
A17_PCORE_FLOPS = 120e9
A17_ECORE_FLOPS = 30e9
A17_EW_FLOPS_PER_THREAD = 0.25e9
A17_BARRIER_S = 25e-6      # ggml per-node thread barrier (2 threads)


def a17_cpu(threads: int) -> HardwareSpec:
    """A17 Pro CPU spec for a given thread count (paper's 1-6 threads).

    Threads land on P-cores first (iOS QoS), then E-cores. Beyond the 6
    physical cores, oversubscription adds scheduling overhead — the
    paper's C5 law.
    """
    p = min(threads, 2)
    e = min(max(threads - 2, 0), 4)
    over = max(threads - 6, 0)
    bw = min(A17_PEAK_BW, p * A17_PCORE_BW + e * A17_ECORE_BW)
    flops = p * A17_PCORE_FLOPS + e * A17_ECORE_FLOPS
    # oversubscription: context-switch penalty degrades both terms
    degrade = 1.0 / (1.0 + 0.15 * over)
    # barrier cost grows with participating threads (cacheline ping-pong)
    barrier = A17_BARRIER_S * (1.0 + 0.35 * max(threads - 2, 0))
    return HardwareSpec(
        name=f"a17-cpu-{threads}t",
        peak_flops=flops * degrade,
        mem_bw=bw * degrade,
        node_overhead_s=barrier if threads > 1 else 2e-6,
        dispatch_overhead_s=30e-6,  # ggml graph_compute launch
        mem_efficiency=0.95,   # sequential weight streaming
        flop_efficiency=0.70,
        ew_flops=A17_EW_FLOPS_PER_THREAD * threads * degrade,
    )


# Apple GPU (6-core, Metal): higher raw FLOPs but pays per-kernel launch
# overhead and achieves lower effective bandwidth on small single-batch
# GEMVs (paper §7.4: "Reduced kernel launch overheads" favor the CPU).
A17_GPU = HardwareSpec(
    name="a17-gpu",
    peak_flops=2.15e12,         # fp16
    mem_bw=A17_PEAK_BW,
    node_overhead_s=5.0e-5,     # Metal kernel launch + encode
    sync_overhead_s=1.5e-3,     # CPU<->GPU boundary sync (paper V3)
    dispatch_overhead_s=1.0e-3,  # command-buffer commit + completion
    mem_efficiency=0.72,        # small-GEMV achieved bandwidth
    flop_efficiency=0.80,
    ew_flops=50e9,              # massively parallel elementwise
)


# ---------------------------------------------------------------------------
# Analytic execution model over a Graph
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class NodeCost:
    node: Node
    compute_s: float
    memory_s: float
    overhead_s: float

    @property
    def total_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.overhead_s


def node_cost(n: Node, hw: HardwareSpec) -> NodeCost:
    from repro.core.graph import Op
    if n.op is Op.MUL_MAT or n.op is Op.GET_ROWS:
        rate = hw.peak_flops * hw.flop_efficiency
    else:
        rate = hw.ew_flops or (hw.peak_flops * hw.flop_efficiency)
    c = n.flops / rate
    m = n.bytes / (hw.mem_bw * hw.mem_efficiency)
    return NodeCost(n, c, m, hw.node_overhead_s)


def graph_time_serial(g: Graph, hw: HardwareSpec) -> float:
    """Paper V0: every node serial, per-node dispatch overhead."""
    return sum(node_cost(n, hw).total_s for n in g.nodes)


def graph_time_wave(g: Graph, hw: HardwareSpec,
                    overlap_efficiency: float = 0.95) -> float:
    """Paper V1/V2: independent nodes in a wave share one dispatch and
    overlap; memory traffic within a wave still serializes on the shared
    memory bus (divided by an overlap efficiency <1)."""
    total = 0.0
    for wave in g.waves():
        costs = [node_cost(g.nodes[i], hw) for i in wave]
        mem = sum(c.memory_s for c in costs)          # bus is shared
        comp = max((c.compute_s for c in costs), default=0.0)
        total += max(comp, mem / overlap_efficiency) + hw.node_overhead_s
    return total


def graph_time_heterogeneous(g: Graph, hw_a: HardwareSpec,
                             hw_b: HardwareSpec,
                             boundary_tags: Tuple[str, ...] = ("ffn",),
                             ) -> float:
    """Paper V3: blocks tagged ``boundary_tags`` run on hw_b, the rest on
    hw_a; every a→b or b→a edge pays hw_b.sync_overhead_s. Reproduces the
    15 → 6 tk/s regression."""
    total = 0.0
    placement = []
    for n in g.nodes:
        on_b = n.block in boundary_tags
        placement.append(on_b)
        hw = hw_b if on_b else hw_a
        total += node_cost(n, hw).total_s
    # boundary crossings
    sync = hw_b.sync_overhead_s or hw_a.sync_overhead_s
    crossings = 0
    for i, n in enumerate(g.nodes):
        for d in n.deps:
            if placement[d] != placement[i]:
                crossings += 1
                break  # one sync per node, not per edge
    return total + crossings * sync


def tokens_per_second(step_time_s: float, tokens: int = 1) -> float:
    return tokens / step_time_s if step_time_s > 0 else float("inf")


# ---------------------------------------------------------------------------
# Megastep amortization (serving decode: one dispatch per K tokens)
# ---------------------------------------------------------------------------

def decode_carry_bytes(cfg, batch: int, kv_len: int,
                       dtype_bytes: int = 2) -> float:
    """Bytes of the decode carry (per-request cache state) the serving
    megastep threads across its dispatch boundary: KV rings for
    attention layers, conv+state for SSM/RG-LRU layers. This is the
    traffic buffer donation halves (see ``megastep_time``)."""
    L, B = cfg.num_layers, batch
    if cfg.arch_type == "ssm":
        conv = (cfg.ssm_conv - 1) * (cfg.d_inner + 2 * cfg.ssm_state) \
            * dtype_bytes
        state = cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
        return float(L * B * (conv + state))
    if cfg.arch_type == "hybrid":
        w = cfg.rglru_width or cfg.d_model
        rglru = 3 * w * dtype_bytes + w * 4
        kv = 2 * cfg.num_kv_heads * cfg.head_dim * \
            min(kv_len, cfg.local_attn_window) * dtype_bytes
        pattern = cfg.layer_pattern()
        n_rglru = sum(1 for k in pattern if k == "rglru")
        return float(B * (n_rglru * rglru + (L - n_rglru) * kv))
    win = cfg.sliding_window or 0
    eff = min(kv_len, win) if win else kv_len
    return float(L * B * 2 * cfg.num_kv_heads * cfg.head_dim * eff
                 * dtype_bytes)


def paged_cache_bytes(live_tokens: float, page_size: int, *,
                      bytes_per_token: float, active_slots: int = 1,
                      max_pages: int = 0,
                      table_entry_bytes: float = 4.0) -> float:
    """Cache bytes held by a paged KV pool serving ``live_tokens``.

    The dense engine preallocates ``slots x max_len`` rows whether or
    not they hold live tokens; paging allocates fixed-size blocks on
    demand, so the footprint tracks the live token count plus three
    overheads the dense layout doesn't pay:

    - internal fragmentation: each active slot's tail page is on
      average half full (``0.5 * page_size`` rows per slot),
    - the block tables (``active_slots x max_pages`` int32 entries),
    - one reserved garbage block (retired/frozen rows are redirected
      there so the scan can write unconditionally).

    ``bytes_per_token`` is the full-model per-token KV footprint
    (all layers, K+V, payload+scale at the serving cache precision) —
    ``decode_carry_bytes(cfg, 1, 1) * stream_ratio`` for attention
    families.
    """
    if page_size <= 0:
        raise ValueError(f"page_size must be positive, got {page_size}")
    pool = (live_tokens + 0.5 * page_size * active_slots
            + page_size) * bytes_per_token
    table = active_slots * max(max_pages, 1) * table_entry_bytes
    return pool + table


def quantized_per_token_s(per_token_s: float, hw: HardwareSpec,
                          weight_bytes: float = 0.0,
                          weight_format: str = "bf16",
                          cache_bytes: float = 0.0,
                          kv_format: str = "bf16",
                          kernel_backend: str = "pallas") -> float:
    """Adjust a bf16-calibrated per-token decode time for weight and/or
    KV-cache precision (paper §5.3: quantization is the single largest
    lever because decode GEMVs are weight-stream-bound; the cache is
    the second stream, and the one that grows with context and batch).

    ``weight_bytes`` / ``cache_bytes`` are the bf16 footprints of the
    two streams read per token. Two precision terms move per stream:
    it shrinks by ``bits_per_weight / 16`` (the memory-roofline win)
    and the in-kernel dequant adds ``dequant_flops_per_weight`` per
    element (the NEON/VREG widen+scale cost — what erodes the Q4 win
    as models grow, Fig 4e; for the cache the same tax applies per K/V
    element read). The subtraction is clamped at zero: this helper
    cannot see the compute/memory split inside ``per_token_s``, so a
    caller whose step is not stream-dominated should pass only the
    stream's share of the bytes (or use the graph-level
    ``scheduler.simulate_precision`` / ``simulate_kv_precision``,
    which model the split).

    ``kernel_backend`` selects how the dequant is *executed*:
    ``"pallas"`` (default — the formulas this module has always used)
    models fused in-register dequant streaming only the quantized
    bytes; ``"xla"`` charges each stream its materialized-unpack bytes
    on top (``PrecisionFormat.effective_stream_ratio``) — the measured
    PR-4 regime where q4_0 KV decoded at 0.75-0.81x bf16.
    """
    saved = 0.0
    dequant = 0.0
    for nbytes, fname in ((weight_bytes, weight_format),
                          (cache_bytes, kv_format)):
        if not nbytes or fname in ("bf16", "f16", "f32"):
            continue
        fmt = get_format(fname)
        ratio = fmt.effective_stream_ratio(kernel_backend)
        saved += nbytes * (1.0 - ratio) \
            / (hw.mem_bw * hw.mem_efficiency)
        dequant += fmt.dequant_flops_per_weight * (nbytes / 2.0) \
            / (hw.peak_flops * hw.flop_efficiency)
    return max(per_token_s - saved, 0.0) + dequant


def megastep_time(per_token_s: float, hw: HardwareSpec, k: int = 1, *,
                  carry_bytes: float = 0.0,
                  donate_carries: bool = True,
                  weight_bytes: float = 0.0,
                  weight_format: str = "bf16",
                  cache_bytes: float = 0.0,
                  kv_format: str = "bf16",
                  kernel_backend: str = "pallas",
                  host_drain_s: float = 0.0,
                  pipeline_depth: int = 1,
                  page_gather_bytes: float = 0.0) -> float:
    """Wall time of one K-token serving megastep: one host dispatch +
    K device-resident decode iterations. The per-token dispatch share
    ``dispatch_overhead_s / k`` is the lever the paper's §5 CPU-vs-GPU
    result measures (per-kernel launch cost at batch-1 decode).

    ``host_drain_s`` is the host-side gap per megastep — draining the
    packed token block (one device→host transfer + the per-token
    Python bookkeeping) and building the next admission arrays. At
    ``pipeline_depth=1`` the gap is serial with the device: it adds
    in full. At depth >= 2 dispatch is async (the drain of megastep N
    overlaps the device running N+1), so the host gap is hidden up to
    the device-step time: the steady-state period per megastep is
    ``max(device_s, host_drain_s)`` plus the dispatch overhead that
    can never be hidden (it sits on the critical path of getting N+1
    enqueued).

    ``carry_bytes`` models the cache/SlotState carry crossing the
    dispatch boundary: without buffer donation the runtime materializes
    the updated carry into fresh buffers (one extra full write per
    dispatch); with ``donate_carries`` the update is in place and the
    boundary term vanishes — halving the carry's HBM traffic, which is
    why the serving engine donates (``jit(..., donate_argnums)``).

    ``weight_bytes`` / ``weight_format`` fold the precision dimension
    into the same napkin math (see :func:`quantized_per_token_s`):
    a Q4 megastep streams 4.5/16 of the bf16 weight bytes per token.
    ``cache_bytes`` / ``kv_format`` do the same for the KV-cache
    stream — a quantized cache also shrinks the *carry* crossing the
    dispatch boundary, so pass a pre-scaled ``carry_bytes`` when the
    carry is the cache (``decode_carry_bytes(...) * stream_ratio``).

    ``page_gather_bytes`` charges the paged-cache indirection tax per
    token: the gather through the block table materializes a dense
    view of the live cache before the attention kernel reads it (one
    pool read + one dense write on top of the kernel's baseline read
    stream) — pass ~2x the live cache-stream bytes, or 0 for the
    dense layout. Paging trades this small bandwidth tax for a
    footprint that scales with live tokens (see
    :func:`paged_cache_bytes`) plus prefix-reuse admission savings.
    """
    per_token_s = quantized_per_token_s(per_token_s, hw, weight_bytes,
                                        weight_format, cache_bytes,
                                        kv_format, kernel_backend)
    if page_gather_bytes:
        per_token_s += page_gather_bytes / (hw.mem_bw
                                            * hw.mem_efficiency)
    boundary = 0.0 if donate_carries else \
        carry_bytes / (hw.mem_bw * hw.mem_efficiency)
    device_s = boundary + k * per_token_s
    if pipeline_depth >= 2:
        return hw.dispatch_overhead_s + max(device_s, host_drain_s)
    return hw.dispatch_overhead_s + device_s + host_drain_s


def megastep_tokens_per_s(per_token_s: float, hw: HardwareSpec,
                          k: int = 1, *, carry_bytes: float = 0.0,
                          donate_carries: bool = True,
                          weight_bytes: float = 0.0,
                          weight_format: str = "bf16",
                          cache_bytes: float = 0.0,
                          kv_format: str = "bf16",
                          kernel_backend: str = "pallas",
                          host_drain_s: float = 0.0,
                          pipeline_depth: int = 1) -> float:
    return tokens_per_second(
        megastep_time(per_token_s, hw, k, carry_bytes=carry_bytes,
                      donate_carries=donate_carries,
                      weight_bytes=weight_bytes,
                      weight_format=weight_format,
                      cache_bytes=cache_bytes,
                      kv_format=kv_format,
                      kernel_backend=kernel_backend,
                      host_drain_s=host_drain_s,
                      pipeline_depth=pipeline_depth), k)


# ---------------------------------------------------------------------------
# Roofline terms (deliverable g)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int
    # amortized host dispatch per step (dispatch_overhead_s divided by
    # steps-per-dispatch; 0 unless the caller models the serving loop)
    dispatch_s: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s,
                 "dispatch": self.dispatch_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        # dispatch is serial with the overlapped device terms
        return max(self.compute_s, self.memory_s,
                   self.collective_s) + self.dispatch_s

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dispatch_s": self.dispatch_s,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "chips": self.chips,
            "dominant": self.dominant,
        }


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             chips: int, hw: HardwareSpec = TPU_V5E,
             links_per_chip: int = 1,
             steps_per_dispatch: int = 0,
             weight_hlo_bytes: float = 0.0,
             weight_format: str = "bf16",
             kv_cache_bytes: float = 0.0,
             kv_format: str = "bf16",
             kernel_backend: str = "pallas") -> RooflineTerms:
    """The brief's three terms, plus an optional dispatch term.

    FLOPs/bytes from ``compiled.cost_analysis()`` are *per device* under
    SPMD; collective_bytes are summed per device from the HLO text.
    ``steps_per_dispatch`` > 0 adds the serving-loop host-launch cost
    amortized over a K-token megastep (K=1 → the paper's losing
    per-token-dispatch configuration).

    ``weight_hlo_bytes`` (the bf16 weight share of ``hlo_bytes``) and
    ``weight_format`` rescale the weight stream by
    ``bits_per_weight / 16`` and add the in-kernel dequant FLOPs —
    the paper's §5.3 quantization lever as a roofline term, so an
    analysis of a bf16-compiled HLO can predict its Q8/Q4 serving
    variant without recompiling. ``kv_cache_bytes`` / ``kv_format``
    apply the identical rescale to the KV-cache share of ``hlo_bytes``
    — the second memory stream, dominant at long context where the
    paper's CPU-vs-GPU crossover lives.

    ``kernel_backend`` picks the dequant execution model: the default
    ``"pallas"`` streams quantized bytes only (fused in-register
    dequant — the formulas below are unchanged from earlier PRs);
    ``"xla"`` charges the materialized bf16 unpack on top via
    ``PrecisionFormat.effective_stream_ratio``.
    """
    mem_bytes, flops = hlo_bytes, hlo_flops
    for nbytes, fname in ((weight_hlo_bytes, weight_format),
                          (kv_cache_bytes, kv_format)):
        if not nbytes or fname in ("bf16", "f16", "f32"):
            continue
        fmt = get_format(fname)
        mem_bytes -= nbytes * (1.0 - fmt.effective_stream_ratio(
            kernel_backend))
        flops += fmt.dequant_flops_per_weight * (nbytes / 2.0)
    return RooflineTerms(
        compute_s=flops / hw.peak_flops,
        memory_s=mem_bytes / hw.mem_bw,
        collective_s=collective_bytes / (hw.link_bw * links_per_chip),
        hlo_flops=flops,
        hlo_bytes=mem_bytes,
        collective_bytes=collective_bytes,
        chips=chips,
        dispatch_s=(hw.dispatch_overhead_s / steps_per_dispatch
                    if steps_per_dispatch else 0.0),
    )


def model_flops(n_params: int, n_tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (N active params for MoE handled by caller)."""
    return 6.0 * n_params * n_tokens
