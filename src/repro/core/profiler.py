"""Op-level profiler — reproduces the paper's §6 breakdown (Figs 5/6).

Given a compute graph and a hardware spec, attribute predicted time to
GGML op classes and to the seven named matmuls per decoder layer
(Qcur, Kcur, Vcur, kqv_out, ffn_gate, ffn_up, ffn_down).

The paper measured, for llama3.2-1B@F16 on the A17 CPU:
  MUL_MAT share = 87.6% (prefill) / 76.2% (decode)
  FFN matmuls (up/down/gate) the largest single contributors.
``tests/test_profiler.py`` asserts our model reproduces those shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core import cost_model as cm
from repro.core.graph import Graph, Op, build_decoder_graph


@dataclasses.dataclass
class ProfileReport:
    phase: str
    total_s: float
    by_op: Dict[str, float]         # op class → seconds
    by_matmul_tag: Dict[str, float]  # named matmul → seconds

    def op_share(self, op: str) -> float:
        return self.by_op.get(op, 0.0) / self.total_s if self.total_s else 0.0

    @property
    def mul_mat_share(self) -> float:
        return self.op_share("MUL_MAT")

    def dominant_matmul(self) -> str:
        return max(self.by_matmul_tag, key=self.by_matmul_tag.get)


def profile_graph(g: Graph, hw: cm.HardwareSpec, phase: str) -> ProfileReport:
    by_op: Dict[str, float] = {}
    by_tag: Dict[str, float] = {}
    total = 0.0
    for n in g.nodes:
        t = cm.node_cost(n, hw).total_s
        by_op[n.op.value] = by_op.get(n.op.value, 0.0) + t
        if n.op is Op.MUL_MAT and n.tag:
            by_tag[n.tag] = by_tag.get(n.tag, 0.0) + t
        total += t
    return ProfileReport(phase, total, by_op, by_tag)


def profile_phases(cfg: ModelConfig, *, threads: int = 2,
                   prompt_len: int = 128, gen_kv: int = 128,
                   weight_format: str = "f16",
                   megastep_k: int = 0,
                   ) -> Dict[str, ProfileReport]:
    """Prefill + decode profiles (the paper's Fig 5a/5b setup).

    ``megastep_k`` > 0 attributes the serving loop's per-step host
    dispatch cost (amortized over a K-token megastep) to a DISPATCH
    pseudo-op in the decode report, so the §6-style breakdown can show
    *why* K=1 per-token dispatch loses — the same mechanism behind the
    paper's §5 GPU-launch-overhead result. 0 keeps the paper figures
    device-time-only.
    """
    hw = cm.a17_cpu(threads)
    prefill = build_decoder_graph(cfg, seq=prompt_len, kv_len=0,
                                  weight_format=weight_format, fused=False)
    decode = build_decoder_graph(cfg, seq=1, kv_len=gen_kv,
                                 weight_format=weight_format, fused=False)
    reports = {
        "prefill": profile_graph(prefill, hw, "prefill"),
        "decode": profile_graph(decode, hw, "decode"),
    }
    if megastep_k > 0:
        reports["decode"] = with_dispatch(reports["decode"], hw,
                                          megastep_k)
    return reports


def with_dispatch(rep: ProfileReport, hw: cm.HardwareSpec,
                  megastep_k: int) -> ProfileReport:
    """Add the amortized host-dispatch share as a DISPATCH pseudo-op."""
    disp = hw.dispatch_overhead_s / max(megastep_k, 1)
    by_op = dict(rep.by_op, DISPATCH=rep.by_op.get("DISPATCH", 0.0) + disp)
    return ProfileReport(f"{rep.phase}_megastep_k{megastep_k}",
                         rep.total_s + disp, by_op, rep.by_matmul_tag)
