"""Token sampling: greedy / temperature / top-k / top-p.

Fully jittable: ``sample`` is pure jnp over a static ``SamplingConfig``
so the serving engine can fuse it into the decode dispatch (logits
never leave the device — the paper's C3/C4 dispatch-overhead lesson).

Contract: logits ``(B, V)`` → tokens ``(B,)`` everywhere (prefill and
decode use the same call; no reshape contortions at call sites).

Stochastic draws fold the batch-row index into the step key, so each
row draws from its own stream regardless of batch width or of which
other rows happen to be active that step. (In the decode megastep the
row IS the slot; in batched prefill it is the position within the
length bucket, so stochastic first tokens depend on bucket grouping.)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0    # 0 → greedy
    top_k: int = 0              # 0 → off
    top_p: float = 1.0          # 1 → off


def sample(logits: jax.Array, rng: jax.Array,
           cfg: SamplingConfig) -> jax.Array:
    """logits: (B, V) → tokens (B,). Pure/jittable (cfg is static)."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k:
        kth = jnp.sort(logits, axis=-1)[:, -cfg.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < cfg.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], 1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    B = logits.shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(jnp.arange(B))
    return jax.vmap(
        lambda l, k: jax.random.categorical(k, l, axis=-1)
    )(logits, keys).astype(jnp.int32)
