"""Token sampling: greedy / temperature / top-k / top-p.

Fully jittable, in two flavors:

- ``sample`` — engine-wide static :class:`SamplingConfig`; branches are
  resolved at trace time (the cheapest path when every row shares one
  config — e.g. a greedy benchmark).
- ``sample_batched`` — **per-row** (per-slot) traced parameters, so one
  continuous-batching decode dispatch can serve heterogeneous requests:
  slot 0 greedy, slot 1 at temperature 1.2/top-k 40, in the same
  ``(B, V)`` logits block. Greedy rows (``temperature <= 0``) are exact
  argmax and never consume randomness, so a request's greedy stream is
  bit-identical regardless of which sampling configs its batch
  neighbours use.

Contract: logits ``(B, V)`` → tokens ``(B,)`` everywhere (prefill and
decode use the same call; no reshape contortions at call sites).

Stochastic draws fold the batch-row index into the step key, so each
row draws from its own stream regardless of batch width or of which
other rows happen to be active that step. (In the decode megastep the
row IS the slot; in batched prefill it is the position within the
length bucket, so stochastic first tokens depend on bucket grouping.)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    temperature: float = 0.0    # 0 → greedy
    top_k: int = 0              # 0 → off
    top_p: float = 1.0          # 1 → off


def sample_batched(logits: jax.Array, rng: jax.Array,
                   temperature: jax.Array, top_k: jax.Array,
                   top_p: jax.Array) -> jax.Array:
    """Per-row sampling: logits (B, V) + per-row params (B,) → (B,).

    Rows with ``temperature <= 0`` return ``argmax`` (no PRNG use);
    ``top_k == 0`` / ``top_p >= 1`` disable the respective filter for
    that row. Filters apply in the same order as the static path
    (top-k, then top-p over the filtered logits) so the two flavors
    draw identical tokens for identical parameters.
    """
    B, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t = jnp.asarray(temperature, jnp.float32)
    k = jnp.asarray(top_k, jnp.int32)
    p = jnp.asarray(top_p, jnp.float32)
    lf = logits.astype(jnp.float32) / jnp.where(t > 0.0, t, 1.0)[:, None]

    # top-k: kth-largest per row via one ascending sort
    asc = jnp.sort(lf, axis=-1)
    kth = jnp.take_along_axis(
        asc, jnp.clip(V - k, 0, V - 1)[:, None], axis=-1)
    lf = jnp.where((k > 0)[:, None] & (lf < kth), -jnp.inf, lf)

    # top-p over the (top-k-filtered) logits
    desc = jnp.sort(lf, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cutoff_idx = jnp.sum(cum < p[:, None], axis=-1)
    cutoff = jnp.take_along_axis(
        desc, jnp.clip(cutoff_idx, 0, V - 1)[:, None], axis=-1)
    lf = jnp.where((p < 1.0)[:, None] & (lf < cutoff), -jnp.inf, lf)

    keys = jax.vmap(lambda i: jax.random.fold_in(rng, i))(jnp.arange(B))
    drawn = jax.vmap(
        lambda l, key: jax.random.categorical(key, l, axis=-1)
    )(lf, keys).astype(jnp.int32)
    return jnp.where(t > 0.0, drawn, greedy)


def sample(logits: jax.Array, rng: jax.Array,
           cfg: SamplingConfig) -> jax.Array:
    """logits: (B, V) → tokens (B,). Pure/jittable (cfg is static)."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    B = logits.shape[0]
    return sample_batched(
        logits, rng,
        jnp.full((B,), cfg.temperature, jnp.float32),
        jnp.full((B,), cfg.top_k, jnp.int32),
        jnp.full((B,), cfg.top_p, jnp.float32))
