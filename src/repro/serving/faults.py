"""Deterministic fault injection for ``ServingEngine``.

The overload/failure layer (preemption, shedding, poisoned-slot
retirement, the block-pool audit) is only trustworthy if its failure
paths actually run — and production faults don't arrive on demand.
This module makes them schedulable and *seeded*: a ``FaultSchedule``
is a plain list of ``FaultEvent``s pinned to megastep indices, either
hand-built (regression tests replay one exact ordering) or drawn from
``FaultSchedule.seeded(seed)`` (chaos property tests sweep seeds, each
seed a reproducible storm). ``FaultInjector`` wraps the engine's step
loop, applies each event at its step, audits the allocator after every
step, and retries transient step faults with bounded exponential
backoff.

Event kinds:

- ``exhaust_pool``  — quarantine ``blocks`` free blocks for
  ``duration`` steps (admissions starve → preemption/putback paths
  fire), then release them. Uses the allocator's first-class
  quarantine owner class so ``engine.audit()`` stays green throughout.
- ``poison_logits`` — NaN the logits of request index ``ridx`` while
  it occupies a slot (in-jit, via ``admit["poison"]``) → the
  finiteness check error-retires it; co-batched survivors must be
  untouched.
- ``preempt``       — force-preempt request index ``ridx`` (evict +
  requeue); the resumed request must stay greedy token-identical.
- ``host_stall``    — sleep ``stall_s`` before the step (a GC pause /
  noisy-neighbor stand-in); the pipelined loop must absorb it without
  corrupting drain ordering.
- ``step_exception``— raise ``TransientStepFault`` *before* the step
  dispatches, ``fires`` times; the injector's bounded retry+backoff
  must recover and the stream must be unaffected (nothing was
  dispatched, so nothing replays).
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, List, Optional

KINDS = ("exhaust_pool", "poison_logits", "preempt", "host_stall",
         "step_exception")


class TransientStepFault(RuntimeError):
    """Injected failure raised before a step dispatches — models a
    recoverable runtime hiccup (allocator race, transient XLA error).
    ``FaultInjector`` retries these with bounded backoff."""


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault. ``step`` is the megastep index (0-based,
    counted by the injector) at which it applies."""
    step: int
    kind: str
    ridx: Optional[int] = None   # request index (poison / preempt)
    blocks: int = 0              # exhaust_pool: blocks to quarantine
    duration: int = 1            # exhaust_pool: steps before release
    stall_s: float = 0.0         # host_stall: sleep length
    fires: int = 1               # step_exception: consecutive raises

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {KINDS})")


@dataclasses.dataclass
class FaultSchedule:
    """An ordered storm of events. ``seeded`` draws a reproducible
    schedule: same seed → same events, so a chaos failure is
    re-runnable by seed alone."""
    events: List[FaultEvent] = dataclasses.field(default_factory=list)

    @classmethod
    def seeded(cls, seed: int, *, n_requests: int, horizon: int = 12,
               n_events: int = 4, paged: bool = True,
               kinds: Optional[tuple] = None) -> "FaultSchedule":
        rng = random.Random(seed)
        pool = list(kinds) if kinds is not None else [
            k for k in KINDS if paged or k != "exhaust_pool"]
        events = []
        poisoned: set = set()
        for _ in range(n_events):
            kind = rng.choice(pool)
            step = rng.randrange(horizon)
            if kind == "exhaust_pool":
                events.append(FaultEvent(
                    step, kind, blocks=rng.randrange(2, 8),
                    duration=rng.randrange(1, 4)))
            elif kind == "poison_logits":
                # at most one poisoned request per schedule keeps the
                # survivor set well-defined for reference pinning
                cands = [i for i in range(n_requests)
                         if i not in poisoned]
                if not cands:
                    continue
                ridx = rng.choice(cands)
                poisoned.add(ridx)
                events.append(FaultEvent(step, kind, ridx=ridx))
            elif kind == "preempt":
                events.append(FaultEvent(
                    step, kind, ridx=rng.randrange(n_requests)))
            elif kind == "host_stall":
                events.append(FaultEvent(
                    step, kind, stall_s=rng.uniform(0.001, 0.01)))
            else:  # step_exception
                events.append(FaultEvent(
                    step, kind, fires=rng.randrange(1, 3)))
        events.sort(key=lambda e: e.step)
        return cls(events)

    @property
    def poisoned_ridx(self) -> set:
        return {e.ridx for e in self.events
                if e.kind == "poison_logits"}


class FaultInjector:
    """Drives ``engine.step()`` under a ``FaultSchedule``.

    ``run(requests)`` submits nothing — callers submit first — but
    needs the request list to resolve each event's ``ridx``. Each loop
    iteration: fire this step's events, raise/retry any pending
    transient fault (bounded ``max_retries`` with exponential backoff
    starting at ``backoff_s``), step the engine, expire elapsed
    ``exhaust_pool`` events, and (when ``audit=True``) run
    ``engine.audit()``. On exit all remaining quarantined blocks are
    released so the pool is fully recoverable."""

    def __init__(self, engine, schedule: FaultSchedule, *,
                 max_retries: int = 3, backoff_s: float = 0.0005,
                 audit: bool = True,
                 sleep: Callable[[float], None] = time.sleep):
        self.engine = engine
        self.schedule = schedule
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.audit = audit
        self._sleep = sleep
        self.steps_run = 0
        self.retries = 0
        self.stalls_s = 0.0
        self._expiries: List = []   # (release_step, blocks)
        self._pending_raises = 0

    def _apply(self, ev: FaultEvent, requests) -> None:
        eng = self.engine
        if ev.kind == "exhaust_pool":
            got = eng.quarantine_blocks(ev.blocks)
            if got:
                self._expiries.append((self.steps_run + ev.duration,
                                       got))
        elif ev.kind == "poison_logits":
            req = requests[ev.ridx]
            if not (req.done or req.cancelled):
                eng.inject_logit_poison(req)
        elif ev.kind == "preempt":
            eng.preempt(requests[ev.ridx])
        elif ev.kind == "host_stall":
            self._sleep(ev.stall_s)
            self.stalls_s += ev.stall_s
        elif ev.kind == "step_exception":
            self._pending_raises += ev.fires

    def _step_with_retry(self) -> None:
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                if self._pending_raises > 0:
                    self._pending_raises -= 1
                    raise TransientStepFault(
                        f"injected transient fault "
                        f"(step {self.steps_run}, attempt {attempt})")
                self.engine.step()
                return
            except TransientStepFault:
                if attempt >= self.max_retries:
                    raise
                self.retries += 1
                self._sleep(delay)
                delay *= 2

    def run(self, requests, max_steps: int = 10000) -> None:
        eng = self.engine
        try:
            while eng.has_work() and self.steps_run < max_steps:
                for ev in self.schedule.events:
                    if ev.step == self.steps_run:
                        self._apply(ev, requests)
                self._step_with_retry()
                self.steps_run += 1
                expired = [e for e in self._expiries
                           if e[0] <= self.steps_run]
                for e in expired:
                    eng.release_quarantined(e[1])
                    self._expiries.remove(e)
                if self.audit:
                    eng.audit()
        finally:
            # pool fully recoverable after the storm, whatever happened
            eng.release_quarantined()
        if self.audit:
            eng.audit()
