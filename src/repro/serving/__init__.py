from repro.serving.engine import ServingEngine, Request, EngineStats
from repro.serving.sampler import SamplingConfig, sample

__all__ = ["ServingEngine", "Request", "EngineStats", "SamplingConfig",
           "sample"]
