from repro.serving.engine import (
    DEFAULT_MEGASTEP_K, PHASE_DECODE, PHASE_IDLE, PHASE_PREFILL,
    EngineStats, Request, ServingEngine, SlotState)
from repro.serving.sampler import SamplingConfig, sample, sample_batched

__all__ = ["ServingEngine", "Request", "EngineStats", "SlotState",
           "SamplingConfig", "sample", "sample_batched",
           "DEFAULT_MEGASTEP_K",
           "PHASE_IDLE", "PHASE_PREFILL", "PHASE_DECODE"]
