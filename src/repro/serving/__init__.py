from repro.serving.engine import (
    DEFAULT_MEGASTEP_K, PHASE_DECODE, PHASE_IDLE, PHASE_PREFILL,
    EngineAuditError, EngineStats, InfeasibleDeadline, PromptTooLong,
    QueueFull, Request, ServingEngine, SlotState, SubmitReject)
from repro.serving.faults import (
    FaultEvent, FaultInjector, FaultSchedule, TransientStepFault)
from repro.serving.sampler import SamplingConfig, sample, sample_batched

__all__ = ["ServingEngine", "Request", "EngineStats", "SlotState",
           "SamplingConfig", "sample", "sample_batched",
           "DEFAULT_MEGASTEP_K",
           "PHASE_IDLE", "PHASE_PREFILL", "PHASE_DECODE",
           "SubmitReject", "QueueFull", "InfeasibleDeadline",
           "PromptTooLong", "EngineAuditError",
           "FaultEvent", "FaultSchedule", "FaultInjector",
           "TransientStepFault"]
