from repro.serving.engine import (
    DEFAULT_MEGASTEP_K, EngineStats, Request, ServingEngine, SlotState)
from repro.serving.sampler import SamplingConfig, sample

__all__ = ["ServingEngine", "Request", "EngineStats", "SlotState",
           "SamplingConfig", "sample", "DEFAULT_MEGASTEP_K"]
