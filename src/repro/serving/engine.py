"""Batched serving engine: slot-based continuous batching with a
device-resident multi-token decode "megastep".

The engine owns a fixed-size decode batch (``slots``). Requests queue
up; free slots are filled by prefilling prompts (length-bucketed, so
several slots splice into the batch cache in ONE dispatch), and every
``step()`` runs one **megastep**: ``megastep_k`` decode iterations
fused into a single jitted ``jax.lax.scan`` that threads (cache,
SlotState) on device and returns a ``(K, slots)`` token block plus
emission masks — one dispatch and one device→host transfer per K
tokens instead of per token.

Why: the paper's §5 headline (2-thread CPU 17 tok/s beats the GPU's
12.8 at batch-1 decode) is a *dispatch-overhead* result, not a FLOPs
result — the GPU loses because every token pays kernel-launch/encode
and a CPU↔GPU sync, exactly the shape of a per-token jitted dispatch
with host-side sampling and ``int()`` syncs. "Understanding LLMs in
Your Pockets" (arXiv:2410.03613) confirms launch amortization is the
dominant mobile-inference lever. The megastep amortizes that fixed
cost K× : sampling runs inside the jit (logits never leave the
device), and EOS/length retirement is handled in-scan by a
length-frozen cache write mask (``decode_step(advance_mask=...)``),
so finished slots emit pad tokens without corrupting their cache.
``core.dispatch.plan`` picks K from the same dispatch-overhead
napkin math the paper's §6 model uses to predict the CPU win.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.serving.sampler import SamplingConfig, sample

# Fallback K when the caller doesn't run the planner: one dispatch per
# 8 tokens keeps Python/XLA launch overhead ≲10% for even the smallest
# models we serve (see core.dispatch.choose_megastep_k).
DEFAULT_MEGASTEP_K = 8

PAD_ID = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1 → never stops early
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    steps: int = 0               # decode substeps executed (K per megastep)
    megasteps: int = 0           # fused decode dispatches
    tokens_generated: int = 0
    prefills: int = 0            # requests prefilled
    prefill_batches: int = 0     # prefill dispatches (≤ prefills)
    decode_wall_s: float = 0.0   # wall time in megastep dispatch + drain


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SlotState:
    """Device-resident per-slot decode state threaded through the
    megastep scan. Mirrors the host's ``active``/``Request`` view; the
    host only touches it between megasteps (slot refill)."""
    last_token: jax.Array   # (slots,) int32 — input token for next step
    gen_len: jax.Array      # (slots,) int32 — tokens generated so far
    max_new: jax.Array      # (slots,) int32
    eos_id: jax.Array       # (slots,) int32
    active: jax.Array       # (slots,) bool
    rng: jax.Array          # PRNG key (one split per decode substep)


def _init_slot_state(slots: int, rng: jax.Array) -> SlotState:
    return SlotState(
        last_token=jnp.zeros((slots,), jnp.int32),
        gen_len=jnp.zeros((slots,), jnp.int32),
        max_new=jnp.zeros((slots,), jnp.int32),
        eos_id=jnp.full((slots,), -1, jnp.int32),
        active=jnp.zeros((slots,), bool),
        rng=rng)


class ServingEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 1024,
                 sampling: SamplingConfig = SamplingConfig(),
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 rng: Optional[jax.Array] = None,
                 megastep_k: Optional[int] = None,
                 megastep_unroll: bool = False):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.sampling = sampling
        self.extra = extra_inputs or {}
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        if megastep_k is not None and int(megastep_k) < 1:
            raise ValueError(
                f"megastep_k must be >= 1 (got {megastep_k}); "
                "K is the number of decode tokens per fused dispatch")
        self.megastep_k = int(megastep_k) if megastep_k else \
            DEFAULT_MEGASTEP_K
        # unrolling the K-substep scan lets XLA fuse *across* decode
        # iterations (deeper amortization than the launch cost alone)
        # at compile time ∝ K — worth it for small dispatch-bound models
        self.megastep_unroll = megastep_unroll

        self.cache = model.init_cache(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: Deque[Request] = collections.deque()
        self.stats = EngineStats()

        self.rng, st_key = jax.random.split(self.rng)
        self.state = _init_slot_state(slots, st_key)

        # recurrent state makes padding unsound → exact-length buckets
        self._pad_prefill = self.cfg.arch_type not in ("ssm", "hybrid")
        window = model.window_for(max_len)
        self._cache_seq = min(max_len, window) if window else max_len

        self._megastep = jax.jit(self._megastep_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # -- batched prefill into free slots ---------------------------------
    def _prefill_impl(self, params, tokens, seq_lens, cache, slot_idx,
                      state, max_new, eos_id):
        """Prefill a length bucket (N, S) in one dispatch: splice its
        cache rows into the batch cache at ``slot_idx`` (N,), sample
        the first token in-jit, and refill the SlotState rows — the
        whole refill is one dispatch and one (N,) host transfer."""
        n = tokens.shape[0]
        one = self.model.init_cache(n, self.max_len)
        batch = {"tokens": tokens, "seq_lens": seq_lens, **{
            k: (jnp.broadcast_to(v[None], (n,) + v.shape)
                if hasattr(v, "shape") else v)
            for k, v in self.extra.items()}}
        logits, one = self.model.prefill(params, batch, one)
        axes = self.model.cache_axes()

        def splice(full, single, ax):
            # the batch axis is named per cache leaf by cache_axes();
            # never guess it from shapes (a leaf with slots==1 or a
            # size-1 non-batch dim would silently mis-splice)
            b = ax.index("batch")
            out = jnp.moveaxis(full, b, 0).at[slot_idx].set(
                jnp.moveaxis(single, b, 0).astype(full.dtype))
            return jnp.moveaxis(out, 0, b)

        new_cache = jax.tree_util.tree_map(splice, cache, one, axes)

        rng, key = jax.random.split(state.rng)
        first = sample(logits, key, self.sampling)
        alive = (first != eos_id) & (max_new > 1)
        new_state = SlotState(
            last_token=state.last_token.at[slot_idx].set(first),
            gen_len=state.gen_len.at[slot_idx].set(1),
            max_new=state.max_new.at[slot_idx].set(max_new),
            eos_id=state.eos_id.at[slot_idx].set(eos_id),
            active=state.active.at[slot_idx].set(alive),
            rng=rng)
        return first, new_cache, new_state

    def _bucket_len(self, prompt_len: int) -> int:
        """Padded bucket length: next power of two (≥8), capped at the
        cache window so padded prefill never hits the ring path. Exact
        length for recurrent archs and over-window prompts."""
        if not self._pad_prefill or prompt_len > self._cache_seq:
            return prompt_len
        return min(max(8, 1 << (prompt_len - 1).bit_length()),
                   self._cache_seq)

    # -- public API --------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        free = [s for s in range(self.slots) if self.active[s] is None]
        taken = []
        while free and self.queue:
            taken.append((free.pop(0), self.queue.popleft()))
        if not taken:
            return
        buckets: Dict[int, List] = {}
        for s, req in taken:
            buckets.setdefault(self._bucket_len(len(req.prompt)),
                               []).append((s, req))
        for blen, group in buckets.items():
            toks = np.full((len(group), blen), PAD_ID, np.int32)
            for i, (_, req) in enumerate(group):
                toks[i, :len(req.prompt)] = req.prompt
            lens = np.asarray([len(r.prompt) for _, r in group], np.int32)
            slot_idx = np.asarray([s for s, _ in group], np.int32)
            maxnew = np.asarray([r.max_new_tokens for _, r in group],
                                np.int32)
            eos = np.asarray([r.eos_id for _, r in group], np.int32)
            first, self.cache, self.state = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                self.cache, jnp.asarray(slot_idx), self.state,
                jnp.asarray(maxnew), jnp.asarray(eos))
            first = np.asarray(first)
            self.stats.prefill_batches += 1

            for i, (s, req) in enumerate(group):
                tok = int(first[i])
                req.output.append(tok)
                self.stats.prefills += 1
                self.stats.tokens_generated += 1
                if tok == req.eos_id or len(req.output) >= \
                        req.max_new_tokens:
                    req.done = True       # first token already ends it
                else:
                    self.active[s] = req

    # -- fused K-token decode ---------------------------------------------
    def _megastep_impl(self, params, cache, state):
        """K decode substeps in one ``lax.scan``: in-jit sampling, per
        slot EOS/length retirement via the frozen-write mask. Returns
        (cache, state, tokens (K, slots), emitted (K, slots))."""
        smp = self.sampling

        def body(carry, _):
            cache, st = carry
            logits, cache = self.model.decode_step(
                params, st.last_token[:, None], cache,
                advance_mask=st.active)
            rng, step_key = jax.random.split(st.rng)
            tok = sample(logits, step_key, smp)
            tok = jnp.where(st.active, tok, jnp.int32(PAD_ID))
            gen_len = st.gen_len + st.active.astype(jnp.int32)
            done_now = st.active & ((tok == st.eos_id) |
                                    (gen_len >= st.max_new))
            new_st = SlotState(
                last_token=jnp.where(st.active, tok, st.last_token),
                gen_len=gen_len, max_new=st.max_new, eos_id=st.eos_id,
                active=st.active & ~done_now, rng=rng)
            return (cache, new_st), (tok, st.active)

        (cache, state), (toks, emitted) = jax.lax.scan(
            body, (cache, state), None, length=self.megastep_k,
            unroll=self.megastep_unroll)
        # pack (tokens, emitted) into one (2, K, slots) block → a single
        # device→host transfer per megastep
        return cache, state, jnp.stack([toks, emitted.astype(jnp.int32)])

    def step(self) -> int:
        """One megastep (up to ``megastep_k`` tokens per active slot);
        drain its token block. Returns #slots still active."""
        self._fill_slots()
        if not any(r is not None for r in self.active):
            return 0
        t0 = time.perf_counter()
        self.cache, self.state, block = self._megastep(
            self.params, self.cache, self.state)
        block = np.asarray(block)        # ONE host transfer per K tokens
        toks, emitted = block[0], block[1].astype(bool)
        self.stats.megasteps += 1
        self.stats.steps += toks.shape[0]
        for k in range(toks.shape[0]):
            for s in range(self.slots):
                req = self.active[s]
                if req is None or not emitted[k, s]:
                    continue
                tok = int(toks[k, s])
                req.output.append(tok)
                self.stats.tokens_generated += 1
                if tok == req.eos_id or len(req.output) >= \
                        req.max_new_tokens:
                    req.done = True      # device already froze this slot
                    self.active[s] = None
        self.stats.decode_wall_s += time.perf_counter() - t0
        return sum(r is not None for r in self.active)

    def run(self, max_steps: int = 10000) -> None:
        """Drain queue + active slots (``max_steps`` megasteps)."""
        for _ in range(max_steps):
            self._fill_slots()
            if not self.queue and not any(
                    r is not None for r in self.active):
                return
            self.step()
