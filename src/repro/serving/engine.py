"""Batched serving engine: slot-based continuous batching.

The engine owns a fixed-size decode batch (``slots``). Requests queue
up; free slots are filled by prefilling the prompt (one sequence at a
time into its slot — per-slot cache insertion), and every ``step()``
decodes one token for all active slots. Finished sequences (EOS or
max_new_tokens) free their slot.

This is the deployment shape of the paper's decode phase: the
throughput the roofline predicts for ``decode_32k`` is this loop's
steady state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.serving.sampler import SamplingConfig, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1 → never stops early
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    tokens_generated: int = 0
    prefills: int = 0


class ServingEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 1024,
                 sampling: SamplingConfig = SamplingConfig(),
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 rng: Optional[jax.Array] = None):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.sampling = sampling
        self.extra = extra_inputs or {}
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)

        self.cache = model.init_cache(slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.last_token = np.zeros((slots,), np.int32)
        self.stats = EngineStats()

        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(self._prefill_impl)

    # -- single-sequence prefill into one slot ---------------------------
    def _prefill_impl(self, params, tokens, cache, slot):
        """Prefill one sequence (1, S) and splice its cache rows into the
        batch cache at ``slot``."""
        one = self.model.init_cache(1, self.max_len)
        batch = {"tokens": tokens, **{
            k: v[None] if hasattr(v, "shape") else v
            for k, v in self.extra.items()}}
        logits, one = self.model.prefill(params, batch, one)

        def splice(full, single):
            # single rows live on axis with size 1; find batch axis by
            # matching shapes: full (..., slots, ...) vs single (..., 1, ...)
            diff = [i for i, (a, b) in enumerate(
                zip(full.shape, single.shape)) if a != b]
            ax = diff[0] if diff else 0
            idx = [slice(None)] * full.ndim
            start = [0] * full.ndim
            start[ax] = slot
            return jax.lax.dynamic_update_slice(
                full, single.astype(full.dtype), tuple(start))

        new_cache = jax.tree_util.tree_map(splice, cache, one)
        return logits[0], new_cache

    # -- public API --------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, self.cache = self._prefill_one(
                    self.params, toks, self.cache, s)
                self.rng, k = jax.random.split(self.rng)
                nxt = int(sample(logits[None], k, self.sampling)[0])
                req.output.append(nxt)
                self.last_token[s] = nxt
                self.stats.prefills += 1
                self.stats.tokens_generated += 1
                if nxt == req.eos_id or len(req.output) >= req.max_new_tokens:
                    req.done = True          # first token already ends it
                else:
                    self.active[s] = req

    def step(self) -> int:
        """One decode step for all active slots. Returns #active."""
        self._fill_slots()
        if not any(r is not None for r in self.active):
            return 0
        toks = jnp.asarray(self.last_token[:, None])
        logits, self.cache = self._decode(self.params, toks, self.cache)
        self.rng, k = jax.random.split(self.rng)
        nxt = np.asarray(sample(logits, k, self.sampling))
        self.stats.steps += 1
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(nxt[s])
            req.output.append(tok)
            self.last_token[s] = tok
            self.stats.tokens_generated += 1
            if tok == req.eos_id or len(req.output) >= req.max_new_tokens:
                req.done = True
                self.active[s] = None
            else:
                n_active += 1
        return n_active

    def run(self, max_steps: int = 10000) -> None:
        """Drain queue + active slots."""
        for _ in range(max_steps):
            self._fill_slots()
            if not self.queue and not any(
                    r is not None for r in self.active):
                return
            self.step()
