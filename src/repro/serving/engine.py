"""Continuous-batching serving engine: chunked prefill admission,
per-slot sampling, and a pipelined device-resident multi-token decode
"megastep" loop with donated carries.

The engine owns a fixed-size decode batch (``slots``). Requests queue
up, and every ``step()`` runs one **megastep**: ``megastep_k`` decode
iterations fused into a single jitted ``jax.lax.scan`` that threads
(cache, SlotState) on device and returns a ``(3, K, slots)`` block of
(tokens, emission mask, prefill progress) — one dispatch and one
device→host transfer per K tokens instead of per token.

**Pipelined dispatch/drain** (``pipeline_depth``): megastep dispatch
is asynchronous under JAX, so ``step()`` is split into a dispatch half
(stage the admission arrays from the host's current slot view, launch
megastep N+1) and a drain half (block on megastep N's packed token
block — the loop's ONE synchronization point, ``np.asarray(block)``).
With ``pipeline_depth=1`` the two halves run back-to-back (the serial
PR-1/2 loop: the device idles while the host unpacks K×slots tokens
and builds the next admission arrays). With ``pipeline_depth=2``
exactly one megastep stays in flight: while the device runs N+1, the
host drains N and stages N+2's admissions — the host-side gap between
device steps (the paper's §5 dispatch-overhead story, on our side of
the fence) is hidden up to the device-step time.

Why token identity survives pipelining: slots are independent, and the
host's view of slot state is allowed to go stale by one megastep.
Admissions staged while N is in flight target N+1's slot view —
a slot the host believes free was already idle (frozen cache, no
emission) throughout N, and a slot retired *inside* N keeps emitting
nothing under the frozen write mask until the host drains N and
observes it. Each in-flight block carries a snapshot of its slot
occupants at dispatch time, so drained tokens are attributed to the
request that actually rode that megastep, and the host prompt-cursor
mirror is only advanced from blocks whose occupant is still the live
request. The per-request token streams are therefore byte-identical
to the serial engine's (the property suite pins depth>1 == depth 1
across every cache family, admission mode and K); only *latency*
moves — a slot freed inside N is refilled at N+2 instead of N+1, and
one trailing all-idle megastep is dispatched per queue drain.

Why: the paper's §5 headline (2-thread CPU 17 tok/s beats the GPU's
12.8 at batch-1 decode) is a *dispatch-overhead* result, not a FLOPs
result — the GPU loses because every token pays kernel-launch/encode
and a CPU↔GPU sync. The megastep amortizes that fixed cost K×, and the
three mechanisms below keep *mixed* prefill/decode traffic — where
"Understanding LLMs in Your Pockets" (arXiv:2410.03613) shows
on-device throughput actually collapses — on the same amortized path:

- **Chunked prefill admission** (``admission="chunked"``, the
  default): prompts ride *inside* the megastep scan. Each slot carries
  a ``phase`` (idle / prefill / decode) plus a ``prefill_pos`` cursor
  and a fixed-size on-device prompt chunk buffer (``prefill_chunk``
  tokens, refreshed by the host between megasteps through the same
  megastep dispatch — zero extra host dispatches). A prefilling slot
  consumes one prompt token per scan substep through
  ``Model.decode_step`` — the same cache-write path decode uses, so
  the existing ``advance_mask`` machinery covers admission for every
  cache family — and emits its first sampled token the substep it
  consumes the last prompt token. Decoding neighbours never stall.
  ``admission="stall"`` keeps the PR-1 behaviour: length-bucketed
  batched prefill dispatches between megasteps (the configuration
  ``benchmarks/serving_bench.py``'s mixed-workload sweep measures
  losing).
- **Per-slot sampling params**: ``temperature`` / ``top_k`` /
  ``top_p`` are SlotState fields threaded through the scanned
  ``sample_batched``, so heterogeneous requests (greedy next to
  temperature 1.2) share one batch; greedy rows stay exact argmax and
  consume no randomness.
- **Donated megastep carries** (``donate_carries=True``): the cache +
  SlotState pytrees are donated into the megastep and prefill jits
  (``donate_argnums``), so XLA updates the multi-MB KV/state carry in
  place instead of writing a second copy — halving the carry's HBM
  traffic at each dispatch boundary. ``core.cost_model.megastep_time``
  accounts the same term analytically.

EOS/length retirement stays in-scan via the length-frozen cache write
mask (``decode_step(advance_mask=...)``), so finished slots emit pad
tokens without corrupting their cache. ``core.dispatch.plan`` picks K
(and the admission mode) from the same dispatch-overhead napkin math
the paper's §6 model uses to predict the CPU win.

**Failure semantics (overload + poisoned requests).** On-device
serving lives permanently near its resource ceiling, so running out is
a steady state to schedule around, not an error to crash on. Three
distinct outcomes, all observable per request:

- **Shed** — ``submit()`` raises a typed reject *before* the request
  holds any resource: ``QueueFull`` when ``max_queue`` is set and the
  queue is at its bound (carries a ``retry_after_s`` hint from the
  engine's measured drain rate), ``InfeasibleDeadline`` when
  ``Request.deadline_s`` cannot be met even by an empty engine, and
  ``PromptTooLong`` when the prompt can never fit the cache (the
  ring/page write would otherwise corrupt the slot's own stream).
  All subclass ``SubmitReject`` (a ``ValueError``). The queue orders
  by earliest deadline first (EDF); deadline-less requests stay FIFO
  behind their submission order.
- **Preempted** — when a paged admission cannot get blocks even after
  registry eviction, the engine may preempt a victim slot (least
  progress, non-shared-prefix first, and only one whose EDF key is
  strictly later than the incoming request's — so preemption can
  never livelock). The victim's slot retires through the frozen-write
  mask, its private blocks are recycled refcount-aware, and the
  request is requeued to recompute from its prompt + generated
  prefix; a greedy preempted-then-resumed request is token-identical
  to an uninterrupted run. ``Request.preemptions`` counts round
  trips; the outcome is otherwise invisible to the caller.
- **Errored** — an in-jit finiteness check on per-slot logits retires
  any slot that produces NaN/inf through the same frozen-write path
  (``Request.error = "nonfinite-logits"``, ``done=True``) while the
  rest of the batch continues untouched; survivors are byte-identical
  to a run without the poisoned request.

``audit()`` checks the allocator invariants (free ∪ quarantined ∪
referenced partitions the pool; refcounts match table references;
block 0 never mapped) after any step — ``serving.faults`` runs it
after every step under chaos schedules, ``launch.serve --audit`` in
production loops.
"""
from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.quant.quantize import QuantizedTensor, quantize_tree
from repro.serving.sampler import SamplingConfig, sample_batched

# Fallback K when the caller doesn't run the planner: one dispatch per
# 8 tokens keeps Python/XLA launch overhead ≲10% for even the smallest
# models we serve (see core.dispatch.choose_megastep_k).
DEFAULT_MEGASTEP_K = 8

PAD_ID = 0

# SlotState.phase values (device-resident slot lifecycle)
PHASE_IDLE = 0      # retired / never filled: cache frozen, no emission
PHASE_PREFILL = 1   # consuming prompt tokens in-scan, no emission yet
PHASE_DECODE = 2    # generating: sample + emit every substep

_INF = float("inf")


class SubmitReject(ValueError):
    """Typed admission reject: the request was refused at ``submit()``
    before holding any engine resource. ``retry_after_s`` is a hint
    (None when the engine has no measured rate yet); ``reason`` names
    the reject class for logging/metrics."""
    reason = "rejected"

    def __init__(self, msg: str, *, uid: Optional[int] = None,
                 retry_after_s: Optional[float] = None,
                 queue_depth: int = 0):
        super().__init__(msg)
        self.uid = uid
        self.retry_after_s = retry_after_s
        self.queue_depth = queue_depth


class QueueFull(SubmitReject):
    """Load shed: the bounded queue (``max_queue``) is at capacity."""
    reason = "queue_full"


class InfeasibleDeadline(SubmitReject):
    """Load shed: ``Request.deadline_s`` cannot be met even if the
    request were admitted immediately (measured service rate)."""
    reason = "infeasible_deadline"


class PromptTooLong(SubmitReject):
    """The prompt can never fit this engine's cache: admitting it
    would write past the slot's rows and corrupt its own stream."""
    reason = "prompt_too_long"


class EngineAuditError(AssertionError):
    """An allocator/scheduler invariant does not hold (see audit())."""


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1 → never stops early
    # per-request sampling overrides (None → engine's SamplingConfig)
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    top_p: Optional[float] = None
    # soft completion deadline in seconds from submit; orders the queue
    # (EDF) and arms the infeasibility shed — the engine never cancels
    # on expiry itself (the front-end's deadline sweep does that)
    deadline_s: Optional[float] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cancelled: bool = False          # retired via ServingEngine.cancel()
    error: Optional[str] = None      # fault status (e.g. poisoned logits)
    preemptions: int = 0             # times evicted + requeued for recompute
    # scheduler-internal: submission order + absolute deadline
    _seq: int = dataclasses.field(default=0, repr=False)
    _deadline_abs: Optional[float] = dataclasses.field(default=None,
                                                       repr=False)


@dataclasses.dataclass
class EngineStats:
    steps: int = 0               # decode substeps executed (K per megastep)
    megasteps: int = 0           # fused decode dispatches
    tokens_generated: int = 0
    prefills: int = 0            # requests admitted (either path)
    prefill_batches: int = 0     # stall-path prefill dispatches
    inscan_admissions: int = 0   # requests admitted inside the megastep
    chunk_refills: int = 0       # prompt chunk buffers refreshed
    cancelled: int = 0           # requests retired via cancel()
    prefix_hits: int = 0         # admissions that reused cached blocks
    prefix_hit_tokens: int = 0   # prompt tokens skipped via shared pages
    blocks_recycled: int = 0     # pool blocks returned to the free list
    preemptions: int = 0         # slots evicted + requeued for recompute
    shed: int = 0                # submits rejected (queue full / deadline)
    poisoned: int = 0            # requests retired on non-finite logits
    decode_wall_s: float = 0.0   # wall time in megastep dispatch + drain
    # pipelining attribution: where the decode wall actually goes
    stage_wall_s: float = 0.0    # host time building admission arrays
    drain_wait_s: float = 0.0    # host blocked on the device→host block
                                 # transfer (shrinks when pipelining
                                 # overlaps drain N with megastep N+1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SlotState:
    """Device-resident per-slot serving state threaded through the
    megastep scan. Mirrors the host's ``active``/``Request`` view; the
    host only touches it between megasteps (slot refill), and then
    only through the megastep's own admission arguments."""
    last_token: jax.Array   # (slots,) int32 — input token for next step
    gen_len: jax.Array      # (slots,) int32 — tokens generated so far
    max_new: jax.Array      # (slots,) int32
    eos_id: jax.Array       # (slots,) int32
    phase: jax.Array        # (slots,) int32 — PHASE_IDLE/PREFILL/DECODE
    prefill_pos: jax.Array  # (slots,) int32 — next prompt index to feed
    prompt_len: jax.Array   # (slots,) int32 — total prompt length
    chunk_base: jax.Array   # (slots,) int32 — prompt index of buf[:, 0]
    prompt_buf: jax.Array   # (slots, prefill_chunk) int32 — prompt chunk
    temperature: jax.Array  # (slots,) float32 — per-slot sampling
    top_k: jax.Array        # (slots,) int32
    top_p: jax.Array        # (slots,) float32
    rng: jax.Array          # PRNG key (one split per decode substep)


def _init_slot_state(slots: int, chunk: int, rng: jax.Array) -> SlotState:
    return SlotState(
        last_token=jnp.zeros((slots,), jnp.int32),
        gen_len=jnp.zeros((slots,), jnp.int32),
        max_new=jnp.zeros((slots,), jnp.int32),
        eos_id=jnp.full((slots,), -1, jnp.int32),
        phase=jnp.full((slots,), PHASE_IDLE, jnp.int32),
        prefill_pos=jnp.zeros((slots,), jnp.int32),
        prompt_len=jnp.zeros((slots,), jnp.int32),
        chunk_base=jnp.zeros((slots,), jnp.int32),
        prompt_buf=jnp.zeros((slots, chunk), jnp.int32),
        temperature=jnp.zeros((slots,), jnp.float32),
        top_k=jnp.zeros((slots,), jnp.int32),
        top_p=jnp.ones((slots,), jnp.float32),
        rng=rng)


class ServingEngine:
    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 1024,
                 sampling: SamplingConfig = SamplingConfig(),
                 extra_inputs: Optional[Dict[str, Any]] = None,
                 rng: Optional[jax.Array] = None,
                 megastep_k: Optional[int] = None,
                 megastep_unroll: bool = False,
                 admission: str = "chunked",
                 prefill_chunk: Optional[int] = None,
                 donate_carries: bool = True,
                 quant_policy: Optional[str] = None,
                 kv_quant: Optional[str] = None,
                 kernels: Optional[str] = None,
                 pipeline_depth: int = 1,
                 page_size: int = 0,
                 cache_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 max_queue: int = 0):
        # Kernel backend is a serving dimension like kv_quant: one
        # switch lights up the whole fused-dequant Pallas path (the
        # quant_matmul decode GEMVs *and* the quantized-KV decode
        # attention kernel) or pins everything to XLA. As with
        # kv_quant, a request that differs from the model's config
        # rebinds the engine to a same-params Model view.
        if kernels is not None:
            if kernels not in ("xla", "pallas"):
                raise ValueError(
                    f"kernels must be 'xla' or 'pallas' (got {kernels!r})")
            if kernels != model.cfg.kernels:
                model = Model(dataclasses.replace(model.cfg,
                                                  kernels=kernels))
        self.kernels = model.cfg.kernels
        # Cache precision is a serving dimension parallel to
        # ``quant_policy`` (the *other* memory-bound decode stream — and
        # the one that grows with context length and batch). The model's
        # cache path keys off ``cfg.kv_quant``, so a requested format
        # that differs from the model's config rebinds the engine to a
        # same-params Model view with the format applied. Recurrent
        # families (ssm/hybrid) serve bf16 state regardless
        # (``Model.kv_quant_effective``).
        if kv_quant is not None:
            if kv_quant not in ("bf16", "q8_0", "q4_0"):
                raise ValueError(
                    f"kv_quant must be bf16|q8_0|q4_0 (got {kv_quant!r})")
            if kv_quant != model.cfg.kv_quant:
                model = Model(dataclasses.replace(model.cfg,
                                                  kv_quant=kv_quant))
        self.model = model
        self.cfg = model.cfg
        self.kv_quant = model.kv_quant_effective()
        # Quantization is a serving dimension (paper §5.3: Q4 halves the
        # memory-roofline cost of the decode GEMVs). ``quant_policy``
        # quantizes the weight pytree on entry; already-quantized leaves
        # pass through untouched, so handing the engine pre-quantized
        # params with a matching policy is a no-op — and a *mismatched*
        # pre-quantized tree is rejected rather than silently served
        # under the wrong label.
        if quant_policy and quant_policy not in ("bf16", "f16", "f32"):
            for leaf in jax.tree_util.tree_leaves(
                    params,
                    is_leaf=lambda x: isinstance(x, QuantizedTensor)):
                if isinstance(leaf, QuantizedTensor) and \
                        leaf.fmt != quant_policy:
                    raise ValueError(
                        f"params already quantized as {leaf.fmt!r}; "
                        f"cannot serve them under quant_policy="
                        f"{quant_policy!r} (re-quantizing int weights "
                        "would compound error — dequantize first)")
            params = quantize_tree(params, quant_policy,
                                   model.cfg.quant_group)
        self.quant_policy = quant_policy or "bf16"
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.sampling = sampling
        self.extra = extra_inputs or {}
        self._init_rng = rng if rng is not None else jax.random.PRNGKey(0)
        if megastep_k is not None and int(megastep_k) < 1:
            raise ValueError(
                f"megastep_k must be >= 1 (got {megastep_k}); "
                "K is the number of decode tokens per fused dispatch")
        self.megastep_k = int(megastep_k) if megastep_k else \
            DEFAULT_MEGASTEP_K
        # unrolling the K-substep scan lets XLA fuse *across* decode
        # iterations (deeper amortization than the launch cost alone)
        # at compile time ∝ K — worth it for small dispatch-bound models
        self.megastep_unroll = megastep_unroll

        if admission not in ("chunked", "stall"):
            raise ValueError(f"admission must be 'chunked' or 'stall' "
                             f"(got {admission!r})")
        # chunked admission feeds raw token ids through decode_step; it
        # cannot synthesize encoder frames / VLM prefix embeddings, so
        # those archs keep the batched-prefill admission path.
        if self.cfg.arch_type in ("audio", "vlm") or self.extra:
            admission = "stall"
        self.admission = admission
        # prompt tokens staged on device per slot; the host refreshes
        # the chunk through the megastep's admission args, so any value
        # >= megastep_k admits without ever starving the scan
        self.prefill_chunk = int(prefill_chunk) if prefill_chunk else \
            max(self.megastep_k, 16)
        self.donate_carries = donate_carries
        # dispatched-but-undrained megasteps the loop keeps in flight:
        # 1 = serial dispatch→drain (the PR-1/2 loop), 2 = double-
        # buffered (drain N overlaps megastep N+1 on device). Host-side
        # orchestration only — the compiled megastep is depth-agnostic,
        # so the attribute may be reassigned between steps.
        if int(pipeline_depth) < 1:
            raise ValueError(
                f"pipeline_depth must be >= 1 (got {pipeline_depth}); "
                "1 is the serial loop, 2 keeps one megastep in flight")
        self.pipeline_depth = int(pipeline_depth)
        # PR 6 measured this combination as pathological on jax-CPU:
        # donating a still-pending computation's output buffer makes
        # the next jit call run inline, serializing exactly the
        # dispatch the pipelining exists to overlap. Warn + override
        # rather than raise so planner-free callers still get a
        # working (and faster) configuration.
        if self.pipeline_depth > 1 and self.donate_carries:
            warnings.warn(
                "pipeline_depth>1 with donate_carries=True serializes "
                "dispatch (donating a pending megastep's carry makes "
                "the next dispatch run inline on this backend); "
                "overriding donate_carries=False", RuntimeWarning,
                stacklevel=2)
            self.donate_carries = donate_carries = False

        # EDF-ordered admission queue; ``max_queue`` bounds it (0 =
        # unbounded — the pre-overload-PR behaviour) and submit() sheds
        # with a typed reject instead of growing it past the bound.
        if int(max_queue) < 0:
            raise ValueError(f"max_queue must be >= 0 (got {max_queue})")
        self.max_queue = int(max_queue)
        self.queue: Deque[Request] = collections.deque()
        # run audit() after every step() (the launch.serve --audit flag)
        self.audit_every_step = False

        # recurrent state makes padding unsound → exact-length buckets
        self._pad_prefill = self.cfg.arch_type not in ("ssm", "hybrid")
        window = model.window_for(max_len)
        self._has_window = bool(window)
        self._cache_seq = min(max_len, window) if window else max_len

        # -- paged KV cache (block pool + per-slot block tables) ------
        # ``page_size`` > 0 virtualizes full-attention caches: total
        # cache memory is ``cache_blocks`` pool blocks (scaling with
        # live tokens, not slots × max_len), slot retirement/cancel
        # recycles blocks through a free list, and — with
        # ``prefix_cache`` — admission maps a prompt's longest cached
        # prefix into the new slot's table copy-on-write. Recurrent and
        # sliding-window families stay structurally dense (a contract
        # no-op, like kv_quant there).
        if int(page_size) < 0:
            raise ValueError(f"page_size must be >= 0 (got {page_size})")
        self.page_size = int(page_size)
        self._eff_page = model.paging_effective(max_len, self.page_size)
        self.paged = bool(self._eff_page)
        if self.paged and self._cache_seq % self._eff_page:
            raise ValueError(
                f"page_size {self._eff_page} must divide the cache "
                f"length {self._cache_seq} so the gathered paged view "
                "stays shape-identical to the dense cache")
        self.max_pages = (self._cache_seq // self._eff_page
                          if self.paged else 0)
        if self.paged:
            default_blocks = self.slots * self.max_pages + 1
            self.cache_blocks = (int(cache_blocks) if cache_blocks
                                 else default_blocks)
            if self.cache_blocks < 2:
                raise ValueError(
                    f"cache_blocks must be >= 2 (got {cache_blocks}): "
                    "block 0 is the reserved garbage block")
        else:
            self.cache_blocks = 0
        # prefix reuse needs chunked admission: only then are a
        # prompt's pages produced by the same compiled megastep every
        # admission path runs, so shared pages are bit-identical to
        # what a fresh prefill would write (the XLA-CPU one-ulp
        # cross-regime caveat in ROADMAP standing notes).
        self.prefix_cache_enabled = bool(
            prefix_cache and self.paged and self.admission == "chunked")
        if prefix_cache and not self.prefix_cache_enabled:
            warnings.warn(
                "prefix_cache requires a paged cache and chunked "
                "admission; disabled for this engine", RuntimeWarning,
                stacklevel=2)

        # donated carries: cache + SlotState are consumed by the
        # dispatch and updated in place (we immediately rebind both).
        # ``all_greedy`` is static: an all-greedy batch (the common
        # serving benchmark configuration) compiles a pure-argmax
        # sampler, skipping sample_batched's per-substep full-vocab
        # sorts; the stochastic variant compiles lazily on first use.
        donate = (1, 2) if donate_carries else ()
        self._megastep = jax.jit(self._megastep_impl,
                                 donate_argnums=donate,
                                 static_argnums=(4,))
        donate_pf = (3, 5) if donate_carries else ()
        self._prefill = jax.jit(self._prefill_impl,
                                donate_argnums=donate_pf)
        self.reset(rng=self._init_rng)

    def reset(self, rng: Optional[jax.Array] = None) -> None:
        """Drop all requests and device state (fresh cache + slots);
        compiled megastep/prefill executables are kept, so a reset
        engine re-serves without re-tracing."""
        if rng is not None:
            self._init_rng = rng
        st_key = jax.random.split(self._init_rng)[1]
        self.cache = self.model.init_cache(
            self.slots, self.max_len, page_size=self.page_size,
            cache_blocks=self.cache_blocks)
        self.state = _init_slot_state(self.slots, self.prefill_chunk,
                                      st_key)
        self.active: List[Optional[Request]] = [None] * self.slots
        # block allocator (paged only): free list + refcounts. Block 0
        # is the reserved garbage block (frozen-row writes land there)
        # and is never handed out. ``_prefix_reg`` maps a prompt
        # prefix's content key → pool block, LRU-ordered; the registry
        # holds its own reference so a cached page survives its
        # original request.
        self._free: List[int] = (list(range(self.cache_blocks - 1, 0, -1))
                                 if self.paged else [])
        self._ref = (np.zeros((self.cache_blocks,), np.int64)
                     if self.paged else None)
        self._slot_blocks: List[List[int]] = [[] for _ in
                                              range(self.slots)]
        self._slot_shared: List[int] = [0] * self.slots
        self._slot_reg_done: List[bool] = [False] * self.slots
        self._prefix_reg: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        # pipelined loop: (device block, slot-occupant snapshot) per
        # dispatched-but-undrained megastep, oldest first
        self._inflight: Deque = collections.deque()
        # host mirror of prefill progress (from the megastep's pos row)
        self._prefill_pos: List[int] = [0] * self.slots
        # the prompt each slot was admitted with (the *effective*
        # prompt: original + pre-preemption tokens for resumed
        # requests) — chunk refills must window over this, not the
        # request's live fields, which keep growing during decode
        self._slot_prompt: List[Optional[np.ndarray]] = \
            [None] * self.slots
        # slots currently serving a stochastic (temperature>0) request;
        # empty → the megastep compiles/runs its argmax-only variant
        self._stochastic_slots: set = set()
        # blocks withheld from the allocator (fault injection / admission
        # headroom) — a first-class owner class the audit partitions on
        self._quarantined: List[int] = []
        # uids whose logits the megastep overwrites with NaN (the
        # fault-injection surface for poisoned-request isolation)
        self._poison_uids: set = set()
        self._submit_seq = 0
        self.queue.clear()
        self.stats = EngineStats()

    def cache_nbytes(self) -> int:
        """Device bytes of the live cache pytree (int8 payload + scale
        leaves for quantized caches) — the measured counterpart of the
        analytic ``cost_model.decode_carry_bytes`` / bits-per-16 ratio
        the kv-precision bench reports."""
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(self.cache))

    # -- block allocator (paged cache) -------------------------------------
    @property
    def blocks_in_use(self) -> int:
        """Pool blocks currently referenced (excl. the garbage block)."""
        if not self.paged:
            return 0
        return self.cache_blocks - 1 - len(self._free)

    def _decref(self, blk: int) -> None:
        self._ref[blk] -= 1
        assert self._ref[blk] >= 0, f"block {blk} refcount underflow"
        if self._ref[blk] == 0:
            self._free.append(blk)
            self.stats.blocks_recycled += 1

    def _release_slot_blocks(self, s: int) -> None:
        """Drop a retired/cancelled slot's references. Blocks shared
        with the prefix registry (or another slot's table) survive —
        only the refcount hitting zero recycles a block."""
        for blk in self._slot_blocks[s]:
            self._decref(blk)
        self._slot_blocks[s] = []
        self._slot_shared[s] = 0
        self._slot_reg_done[s] = False

    def _reserve_blocks(self, n: int) -> bool:
        """Ensure ``n`` free blocks, evicting LRU prefix-registry
        entries if needed (an evicted page still referenced by a live
        slot is only unhooked from the registry, not recycled)."""
        while len(self._free) < n and self._prefix_reg:
            _, blk = self._prefix_reg.popitem(last=False)
            self._decref(blk)
        return len(self._free) >= n

    def _admit_paged(self, s: int, req: Request) -> Optional[int]:
        """Allocate the slot's block table for ``req``; returns the
        admission start position (> 0 on a prefix hit: that many prompt
        tokens are already cached in shared pages) or None when the
        pool cannot supply enough blocks even after registry eviction
        (the caller re-queues the request — FIFO blocking)."""
        P = self._eff_page
        # effective view: a resumed request re-prefills its generated
        # prefix too, and only its remaining budget still allocates
        prompt = self._eff_prompt(req)
        max_new = self._eff_max_new(req)
        need = min(len(prompt) + max_new, self._cache_seq)
        n_pages = -(-need // P)
        # a request that outgrows the cache wraps its ring cursor back
        # over its own leading pages — those pages must be exclusively
        # owned (no sharing in, no registration out)
        wraps = len(prompt) + max_new > self._cache_seq
        shared: List = []
        if self.prefix_cache_enabled and not wraps:
            # longest cached prefix, capped so >= 1 prompt token is
            # left to feed (the scan emits the first sampled token the
            # substep it consumes the last prompt token)
            for i in range((len(prompt) - 1) // P):
                key = prompt[:(i + 1) * P].tobytes()
                blk = self._prefix_reg.get(key)
                if blk is None:
                    break
                shared.append((key, blk))
        if not self._reserve_blocks(n_pages - len(shared)):
            return None
        blocks = []
        for key, blk in shared:
            self._ref[blk] += 1
            self._prefix_reg.move_to_end(key)
            blocks.append(blk)
        for _ in range(n_pages - len(shared)):
            blk = self._free.pop()
            self._ref[blk] = 1
            blocks.append(blk)
        self._slot_blocks[s] = blocks
        self._slot_shared[s] = len(shared)
        self._slot_reg_done[s] = wraps or not self.prefix_cache_enabled
        start = len(shared) * P
        if start:
            self.stats.prefix_hits += 1
            self.stats.prefix_hit_tokens += start
        return start

    def _register_prefix(self, s: int, req: Request) -> None:
        """Publish the slot's fully-prefilled prompt pages into the
        prefix registry (chunked admission only — see __init__). Runs
        once per request, when the drained pos mirror shows the prompt
        fully consumed, i.e. after the pages' contents exist on
        device. Decode writes land at pos >= prompt_len, so published
        pages are never written again by this slot (copy-on-write for
        free)."""
        self._slot_reg_done[s] = True
        prompt = np.asarray(req.prompt, np.int32)
        P = self._eff_page
        blocks = self._slot_blocks[s]
        for i in range(len(prompt) // P):
            key = prompt[:(i + 1) * P].tobytes()
            if key in self._prefix_reg:
                self._prefix_reg.move_to_end(key)
                continue
            self._ref[blocks[i]] += 1       # the registry's reference
            self._prefix_reg[key] = blocks[i]

    def _slot_table_row(self, s: int) -> np.ndarray:
        row = np.zeros((self.max_pages,), np.int32)
        blocks = self._slot_blocks[s]
        row[:len(blocks)] = blocks
        return row

    def quarantine_blocks(self, n: int) -> int:
        """Withhold up to ``n`` free blocks from the allocator (returns
        how many were taken). Quarantined blocks are a first-class
        owner class: admissions can't use them, audit() accounts them,
        ``release_quarantined`` returns them. This is the allocator-
        exhaustion fault-injection surface, and doubles as an admission
        headroom reservation."""
        if not self.paged:
            return 0
        n = min(int(n), len(self._free))
        for _ in range(n):
            self._quarantined.append(self._free.pop())
        return n

    def release_quarantined(self, n: Optional[int] = None) -> int:
        """Return up to ``n`` quarantined blocks (all when None) to the
        free list; returns how many were released."""
        k = len(self._quarantined) if n is None else \
            min(int(n), len(self._quarantined))
        for _ in range(k):
            self._free.append(self._quarantined.pop())
        return k

    def audit(self) -> None:
        """Invariant checker (raises ``EngineAuditError``): the free
        list ∪ quarantine ∪ referenced blocks partitions the pool,
        every refcount equals the number of live references (slot
        tables + prefix registry), block 0 is never handed out, no
        request is simultaneously active and queued, and no empty slot
        holds blocks. Host-side structures only — safe to run between
        steps even with a megastep in flight."""
        for s, r in enumerate(self.active):
            if r is None:
                continue
            if r.done or r.cancelled:
                raise EngineAuditError(
                    f"slot {s}: active request {r.uid} is already done")
            if any(q is r for q in self.queue):
                raise EngineAuditError(
                    f"request {r.uid} is both active (slot {s}) and "
                    "queued — a preemption/requeue double-entry")
        if not self.paged:
            return
        refs = np.zeros((self.cache_blocks,), np.int64)
        for s, blocks in enumerate(self._slot_blocks):
            if blocks and self.active[s] is None:
                raise EngineAuditError(
                    f"slot {s}: empty slot still holds blocks {blocks}")
            for b in blocks:
                if not 1 <= b < self.cache_blocks:
                    raise EngineAuditError(
                        f"slot {s}: table maps block {b} (0 is the "
                        "reserved garbage block)")
                refs[b] += 1
        for b in self._prefix_reg.values():
            refs[b] += 1
        free, quar = set(self._free), set(self._quarantined)
        if len(free) != len(self._free):
            raise EngineAuditError("duplicate block in the free list")
        if len(quar) != len(self._quarantined):
            raise EngineAuditError("duplicate block in quarantine")
        if 0 in free or 0 in quar or refs[0] or self._ref[0]:
            raise EngineAuditError("block 0 escaped the garbage role")
        for b in range(1, self.cache_blocks):
            if self._ref[b] != refs[b]:
                raise EngineAuditError(
                    f"block {b}: refcount {int(self._ref[b])} != "
                    f"{int(refs[b])} live references")
            owners = (b in free) + (b in quar) + (refs[b] > 0)
            if owners != 1:
                raise EngineAuditError(
                    f"block {b}: {owners} owners (free={b in free}, "
                    f"quarantined={b in quar}, refs={int(refs[b])}) — "
                    "the pool partition is broken")

    # -- preemption / resume helpers ---------------------------------------
    def _eff_prompt(self, req: Request) -> np.ndarray:
        """Admission-time prompt: the original prompt plus any tokens
        already generated before a preemption. Re-feeding the generated
        prefix through the same decode path rebuilds the cache
        bit-identically, so a resumed greedy request continues exactly
        where an uninterrupted run would."""
        prompt = np.asarray(req.prompt, np.int32)
        if not req.output:
            return prompt
        return np.concatenate(
            [prompt, np.asarray(req.output, np.int32)])

    def _eff_max_new(self, req: Request) -> int:
        """In-slot generation budget: total minus already-emitted."""
        return req.max_new_tokens - len(req.output)

    def _edf_key(self, req: Request):
        d = req._deadline_abs if req._deadline_abs is not None else _INF
        return (d, req._seq)

    def _enqueue(self, req: Request) -> None:
        """Insert keeping the queue sorted by (deadline, submission
        order) — plain FIFO when no request carries a deadline."""
        key = self._edf_key(req)
        if not self.queue or self._edf_key(self.queue[-1]) <= key:
            self.queue.append(req)
            return
        for i, r in enumerate(self.queue):
            if self._edf_key(r) > key:
                self.queue.insert(i, req)
                return
        self.queue.append(req)

    def _measured_substep_s(self) -> Optional[float]:
        """Measured wall seconds per decode substep (None before any
        megastep has run) — the basis for retry-after hints and the
        infeasible-deadline shed."""
        if self.stats.steps == 0 or self.stats.decode_wall_s == 0.0:
            return None
        return self.stats.decode_wall_s / self.stats.steps

    def _service_substeps(self, req: Request) -> int:
        """Substeps a request occupies a slot for: chunked admission
        rides the prompt in-scan (one token per substep), stall
        prefills in one dispatch."""
        gen = max(self._eff_max_new(req), 1)
        if self.admission == "chunked":
            return len(self._eff_prompt(req)) + gen
        return gen

    def _pick_victim(self, incoming: Request) -> Optional[int]:
        """Preemption victim for a pool-starved admission: only slots
        whose EDF key is strictly *later* than the incoming request's
        are eligible (later deadline, or same-class but younger), so a
        preempted-and-requeued request can never be preempted back by
        the one that displaced it — no livelock. Among eligible slots:
        non-shared-prefix first (frees more private blocks, loses no
        registry value), then least progress (least recompute)."""
        key = self._edf_key(incoming)
        cands = [s for s, r in enumerate(self.active)
                 if r is not None and not r.done
                 and self._edf_key(r) > key]
        if not cands:
            return None
        return min(cands, key=lambda s: (
            self._slot_shared[s] > 0,
            len(self.active[s].output) + self._prefill_pos[s]))

    def _preempt_slot(self, s: int,
                      admit: Optional[Dict[str, np.ndarray]] = None
                      ) -> Request:
        """Evict slot ``s``: freeze its cache through the same
        PHASE_IDLE path cancel/EOS use (any in-flight megastep keeps
        emitting its pre-preemption tokens, which the drain appends
        before the request is readmitted), recycle its private blocks,
        and requeue the request to recompute from prompt + generated
        prefix. Clears the slot's staged chunk-refill entry when the
        admission arrays are already built."""
        req = self.active[s]
        self.state = dataclasses.replace(
            self.state, phase=self.state.phase.at[s].set(PHASE_IDLE))
        self.active[s] = None
        self._stochastic_slots.discard(s)
        self._prefill_pos[s] = 0
        self._slot_prompt[s] = None
        if self.paged:
            self._release_slot_blocks(s)
        if admit is not None:
            admit["refill"][s] = False
            admit["tokens"][s, :] = 0
            admit["base"][s] = 0
        req.preemptions += 1
        self.stats.preemptions += 1
        self._enqueue(req)
        return req

    def preempt(self, req: Request) -> bool:
        """Preempt an active request (the mechanism behind pool-starved
        admission; also the fault injector's ``preempt`` event).
        Returns False when the request isn't occupying a slot. The
        request resumes via the normal queue — token-identical under
        greedy sampling."""
        if req.done or req.cancelled:
            return False
        for s, r in enumerate(self.active):
            if r is req:
                self._preempt_slot(s)
                return True
        return False

    def inject_logit_poison(self, req: Request) -> None:
        """Fault-injection hook: overwrite this request's logits with
        NaN inside the megastep (while it occupies a slot) so the
        in-jit finiteness check retires it — the deterministic way to
        exercise poisoned-request isolation."""
        self._poison_uids.add(req.uid)

    # -- per-request sampling ----------------------------------------------
    def _req_sampling(self, req: Request):
        smp = self.sampling
        return (
            smp.temperature if req.temperature is None else req.temperature,
            smp.top_k if req.top_k is None else req.top_k,
            smp.top_p if req.top_p is None else req.top_p)

    # -- batched prefill into free slots (admission="stall") ---------------
    def _prefill_impl(self, params, tokens, seq_lens, cache, slot_idx,
                      state, max_new, eos_id, temp, top_k, top_p,
                      table_rows):
        """Prefill a length bucket (N, S) in one dispatch: splice its
        cache rows into the batch cache at ``slot_idx`` (N,), sample
        the first token in-jit, and refill the SlotState rows — the
        whole refill is one dispatch and one (N,) host transfer.

        Paged engines prefill into a *dense* scratch cache (the model's
        prefill path is structure-driven), then scatter its rows
        page-wise into the pool blocks named by ``table_rows``
        (N, max_pages) — dense engines ignore that argument."""
        n = tokens.shape[0]
        one = self.model.init_cache(n, self.max_len)
        batch = {"tokens": tokens, "seq_lens": seq_lens, **{
            k: (jnp.broadcast_to(v[None], (n,) + v.shape)
                if hasattr(v, "shape") else v)
            for k, v in self.extra.items()}}
        logits, one = self.model.prefill(params, batch, one)
        if self.paged:
            new_cache = self._paged_splice(cache, one, slot_idx,
                                           table_rows)
        else:
            axes = self.model.cache_axes()

            def splice(full, single, ax):
                # the batch axis is named per cache leaf by
                # cache_axes(); never guess it from shapes (a leaf with
                # slots==1 or a size-1 non-batch dim would silently
                # mis-splice)
                b = ax.index("batch")
                out = jnp.moveaxis(full, b, 0).at[slot_idx].set(
                    jnp.moveaxis(single, b, 0).astype(full.dtype))
                return jnp.moveaxis(out, 0, b)

            new_cache = jax.tree_util.tree_map(splice, cache, one, axes)

        rng, key = jax.random.split(state.rng)
        first = sample_batched(logits, key, temp, top_k, top_p)
        alive = (first != eos_id) & (max_new > 1)
        phase = jnp.where(alive, PHASE_DECODE, PHASE_IDLE)
        new_state = dataclasses.replace(
            state,
            last_token=state.last_token.at[slot_idx].set(first),
            gen_len=state.gen_len.at[slot_idx].set(1),
            max_new=state.max_new.at[slot_idx].set(max_new),
            eos_id=state.eos_id.at[slot_idx].set(eos_id),
            phase=state.phase.at[slot_idx].set(phase),
            prefill_pos=state.prefill_pos.at[slot_idx].set(
                seq_lens.astype(jnp.int32)),
            prompt_len=state.prompt_len.at[slot_idx].set(
                seq_lens.astype(jnp.int32)),
            temperature=state.temperature.at[slot_idx].set(temp),
            top_k=state.top_k.at[slot_idx].set(top_k),
            top_p=state.top_p.at[slot_idx].set(top_p),
            rng=rng)
        return first, new_cache, new_state

    def _paged_splice(self, cache, one, slot_idx, table_rows):
        """Scatter a dense prefilled scratch cache into the paged live
        cache: K/V (and scale) rows are cut into page_size chunks and
        written to the pool blocks the admitted slots' tables name;
        ``lens`` and ``block_table`` rows are spliced per slot. Pages
        past a slot's allocation map to table entry 0 — the garbage
        block — so over-long (length-bucketed) scratch rows land
        harmlessly there."""
        P = self._eff_page
        live, scratch = cache["layers"], one["layers"]
        out = dict(live)
        S = scratch["k"].shape[3]
        n_pages = min(-(-S // P), self.max_pages)
        for name in ("k", "v", "k_scale", "v_scale"):
            if name not in live:
                continue
            src = scratch[name].astype(live[name].dtype)
            L, n, Hkv, _, d = src.shape
            pad = n_pages * P - S
            if pad > 0:
                src = jnp.pad(src, ((0, 0), (0, 0), (0, 0), (0, pad),
                                    (0, 0)))
            elif pad < 0:
                src = src[:, :, :, :n_pages * P]
            src = src.reshape(L, n, Hkv, n_pages, P, d)
            src = jnp.moveaxis(src, 3, 2)    # (L, n, n_pages, Hkv, P, d)
            out[name] = live[name].at[:, table_rows[:, :n_pages]].set(src)
        out["lens"] = live["lens"].at[:, slot_idx].set(
            scratch["lens"].astype(live["lens"].dtype))
        out["block_table"] = live["block_table"].at[:, slot_idx].set(
            table_rows[None].astype(jnp.int32))
        new_cache = dict(cache, layers=out)
        for name in ("cross_k", "cross_v", "cross_lens"):
            if name in cache:
                b = 0 if name == "cross_lens" else 1
                merged = jnp.moveaxis(cache[name], b, 0).at[slot_idx].set(
                    jnp.moveaxis(one[name], b, 0).astype(
                        cache[name].dtype))
                new_cache[name] = jnp.moveaxis(merged, 0, b)
        return new_cache

    def _bucket_len(self, prompt_len: int) -> int:
        """Padded bucket length: next power of two (≥8), capped at the
        cache window so padded prefill never hits the ring path. Exact
        length for recurrent archs and over-window prompts."""
        if not self._pad_prefill or prompt_len > self._cache_seq:
            return prompt_len
        return min(max(8, 1 << (prompt_len - 1).bit_length()),
                   self._cache_seq)

    # -- public API --------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request. Admission edge cases are resolved here, not
        in-scan: an empty prompt is rejected (it would feed a junk PAD
        token through ``decode_step`` into cache position 0), and
        ``max_new_tokens=0`` short-circuits to an empty completed
        output (the in-scan path checks ``gen_len >= max_new`` only
        *after* emission, so an admitted zero-budget request would
        still emit one token). Overload rejects are typed (see the
        module docstring's failure-semantics section): ``PromptTooLong``
        for prompts that can never fit, ``QueueFull`` at the
        ``max_queue`` bound, ``InfeasibleDeadline`` when
        ``req.deadline_s`` can't be met by an empty engine."""
        if len(np.asarray(req.prompt)) == 0:
            raise ValueError(
                f"request {req.uid}: empty prompt — decode needs at "
                "least one prompt token (admitting one would write a "
                "junk PAD embedding into cache position 0)")
        if req.max_new_tokens < 0:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be >= 0 "
                f"(got {req.max_new_tokens})")
        if req.max_new_tokens == 0:
            req.done = True          # nothing to generate: legal no-op
            return
        prompt_len = len(np.asarray(req.prompt))
        if self.paged:
            need = min(prompt_len + req.max_new_tokens, self._cache_seq)
            pages = -(-need // self._eff_page)
            if pages > self.cache_blocks - 1:
                raise PromptTooLong(
                    f"request {req.uid}: needs {pages} cache pages but "
                    f"the pool holds {self.cache_blocks - 1} — it can "
                    "never be admitted (raise cache_blocks or shrink "
                    "the request)", uid=req.uid)
        elif (not self._has_window
                and self.cfg.arch_type not in ("ssm", "hybrid")
                and prompt_len > self._cache_seq):
            # full-attention dense cache: prefilling past the slot's
            # rows scatters out of range and corrupts the stream —
            # reject at admission instead (windowed/recurrent caches
            # wrap/accumulate legally, paged caches ring over their
            # own pages)
            raise PromptTooLong(
                f"request {req.uid}: prompt of {prompt_len} tokens "
                f"exceeds the cache capacity {self._cache_seq} "
                f"(max_len={self.max_len}) — the prefill write would "
                "corrupt the slot's cache; raise max_len or truncate",
                uid=req.uid)
        if self.max_queue and len(self.queue) >= self.max_queue:
            self.stats.shed += 1
            sub = self._measured_substep_s()
            hint = None
            if sub is not None:
                backlog = sum(self._service_substeps(r)
                              for r in self.queue)
                hint = sub * backlog / max(self.slots, 1)
            raise QueueFull(
                f"request {req.uid}: queue at its bound "
                f"({self.max_queue}) — shed to protect latency; retry "
                f"after {hint if hint is not None else 'the drain'}",
                uid=req.uid, retry_after_s=hint,
                queue_depth=len(self.queue))
        if req.deadline_s is not None:
            sub = self._measured_substep_s()
            est = (sub or 0.0) * self._service_substeps(req)
            if req.deadline_s <= 0 or est > req.deadline_s:
                self.stats.shed += 1
                raise InfeasibleDeadline(
                    f"request {req.uid}: deadline {req.deadline_s:.3f}s "
                    f"< estimated service {est:.3f}s even unqueued — "
                    "shed instead of generating tokens it can't use",
                    uid=req.uid, queue_depth=len(self.queue))
            req._deadline_abs = time.monotonic() + req.deadline_s
        req._seq = self._submit_seq
        self._submit_seq += 1
        self._enqueue(req)

    def cancel(self, req: Request) -> bool:
        """Retire a request immediately. A queued request is removed
        from the queue; an active one has its slot forced to
        ``PHASE_IDLE`` — the same frozen-write retirement the in-scan
        EOS/length path uses, so the remaining substeps of any
        in-flight megastep leave its cache untouched and its late
        tokens are dropped at drain time. The freed slot is refilled
        at the next admission. Returns True if the request was live.

        Cancel composes with preemption: a request cancelled while
        mid-preemption (requeued, blocks already recycled) takes the
        queue path below — its slot and blocks were released at
        preemption time, so nothing double-frees; a request preempted
        after being cancelled is impossible (``preempt`` refuses
        cancelled requests)."""
        if req.done:
            return False
        self._poison_uids.discard(req.uid)
        try:
            self.queue.remove(req)
            req.done = req.cancelled = True
            self.stats.cancelled += 1
            return True
        except ValueError:
            pass
        for s, r in enumerate(self.active):
            if r is req:
                self.state = dataclasses.replace(
                    self.state,
                    phase=self.state.phase.at[s].set(PHASE_IDLE))
                self.active[s] = None
                self._stochastic_slots.discard(s)
                self._slot_prompt[s] = None
                if self.paged:
                    # recycle the slot's blocks; prefix pages shared
                    # with the registry or another slot survive (their
                    # refcount stays > 0)
                    self._release_slot_blocks(s)
                req.done = req.cancelled = True
                self.stats.cancelled += 1
                return True
        return False

    @property
    def in_flight(self) -> int:
        """Megasteps dispatched but not yet drained (< pipeline_depth
        except transiently inside ``step()``)."""
        return len(self._inflight)

    def has_work(self) -> bool:
        """True while anything is queued, occupying a slot, or riding
        an undrained megastep — the front-end's idle test."""
        return bool(self.queue) or bool(self._inflight) or \
            any(r is not None for r in self.active)

    def _take_free(self) -> List:
        free = [s for s in range(self.slots) if self.active[s] is None]
        # a preempted request still riding an undrained megastep's
        # occupant snapshot must not be readmitted yet: that drain will
        # append its pre-preemption tokens, and a premature resume
        # would re-generate them (duplicated output, early retirement)
        pending = {id(r) for _, occ in self._inflight
                   for r in occ if r is not None}
        taken, held = [], []
        while free and self.queue:
            req = self.queue.popleft()
            # a preempted-then-finished (or late-cancelled) request can
            # still sit in the queue: drop it without burning a slot
            if req.done or req.cancelled:
                continue
            if id(req) in pending:
                held.append(req)     # resume after its block drains
                continue
            taken.append((free.pop(0), req))
        # held requests were popped from the head, so putting them back
        # at the head in order preserves the EDF sort
        self.queue.extendleft(reversed(held))
        return taken

    def _fill_slots_stall(self) -> None:
        """PR-1 admission: length-bucketed prefill dispatches that run
        between megasteps — and stall every decoding slot meanwhile.
        Resumed (preempted) requests prefill their prompt + generated
        prefix and keep only their remaining budget in-slot."""
        taken = self._take_free()
        if self.paged and taken:
            # allocate block tables up front; a request the pool cannot
            # serve preempts an eligible victim (see _pick_victim) or
            # goes back to the queue head (FIFO blocking — later
            # requests must not jump an admission-starved head)
            admitted, putback = [], []
            for s, req in taken:
                if putback:
                    putback.append(req)
                    continue
                res = self._admit_paged(s, req)
                while res is None:
                    v = self._pick_victim(req)
                    if v is None:
                        break
                    self._preempt_slot(v)
                    res = self._admit_paged(s, req)
                if res is None:
                    putback.append(req)
                else:
                    admitted.append((s, req))
            self.queue.extendleft(reversed(putback))
            taken = admitted
        if not taken:
            return
        buckets: Dict[int, List] = {}
        for s, req in taken:
            p = self._eff_prompt(req)
            buckets.setdefault(self._bucket_len(len(p)),
                               []).append((s, req, p))
        for blen, group in buckets.items():
            toks = np.full((len(group), blen), PAD_ID, np.int32)
            for i, (_, _, p) in enumerate(group):
                toks[i, :len(p)] = p
            lens = np.asarray([len(p) for _, _, p in group], np.int32)
            slot_idx = np.asarray([s for s, _, _ in group], np.int32)
            maxnew = np.asarray([self._eff_max_new(r)
                                 for _, r, _ in group], np.int32)
            eos = np.asarray([r.eos_id for _, r, _ in group], np.int32)
            smp = [self._req_sampling(r) for _, r, _ in group]
            temp = np.asarray([v[0] for v in smp], np.float32)
            topk = np.asarray([v[1] for v in smp], np.int32)
            topp = np.asarray([v[2] for v in smp], np.float32)
            rows = (np.stack([self._slot_table_row(s)
                              for s, _, _ in group])
                    if self.paged
                    else np.zeros((len(group), 0), np.int32))
            first, self.cache, self.state = self._prefill(
                self.params, jnp.asarray(toks), jnp.asarray(lens),
                self.cache, jnp.asarray(slot_idx), self.state,
                jnp.asarray(maxnew), jnp.asarray(eos),
                jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(topp),
                jnp.asarray(rows))
            first = np.asarray(first)
            self.stats.prefill_batches += 1

            for i, (s, req, p) in enumerate(group):
                tok = int(first[i])
                req.output.append(tok)
                self.stats.prefills += 1
                self.stats.tokens_generated += 1
                self._prefill_pos[s] = len(p)
                if tok == req.eos_id or len(req.output) >= \
                        req.max_new_tokens:
                    req.done = True       # first token already ends it
                    if self.paged:
                        self._release_slot_blocks(s)
                else:
                    self.active[s] = req
                    self._slot_prompt[s] = p
                    if self._req_sampling(req)[0] > 0.0:
                        self._stochastic_slots.add(s)

    def _empty_admit(self) -> Dict[str, np.ndarray]:
        n, c = self.slots, self.prefill_chunk
        admit = {"new": np.zeros((n,), bool),
                 "refill": np.zeros((n,), bool),
                 "tokens": np.zeros((n, c), np.int32),
                 "base": np.zeros((n,), np.int32),
                 "prompt_len": np.zeros((n,), np.int32),
                 "max_new": np.zeros((n,), np.int32),
                 "eos": np.full((n,), -1, np.int32),
                 "temp": np.zeros((n,), np.float32),
                 "top_k": np.zeros((n,), np.int32),
                 "top_p": np.ones((n,), np.float32),
                 # slots whose logits the fault injector corrupts
                 # in-jit (NaN) this megastep — exercises the same
                 # finiteness-retirement path a real nonfinite model
                 # output would take
                 "poison": np.zeros((n,), bool)}
        if self.paged:
            # fresh slots' admission start (cached-prefix length) and
            # block-table rows ride the same megastep arguments
            admit["start_pos"] = np.zeros((n,), np.int32)
            admit["block_table"] = np.zeros((n, self.max_pages),
                                            np.int32)
        return admit

    def _fill_slots_chunked(self) -> Dict[str, np.ndarray]:
        """Build the megastep's admission arguments: next prompt chunk
        for slots mid-prefill, first chunk + metadata for fresh
        requests. No model dispatch happens here — the arrays ride into
        the already-scheduled megastep, so admission costs zero host
        dispatches beyond the megastep cadence."""
        admit = self._empty_admit()
        chunk = self.prefill_chunk
        # refresh the chunk window for slots still consuming a prompt
        # (windowed over the admitted effective prompt — a resumed
        # request's live fields keep growing during decode)
        for s, req in enumerate(self.active):
            if req is None:
                continue
            pos = self._prefill_pos[s]
            prompt = self._slot_prompt[s]
            if prompt is None or pos >= len(prompt):
                continue
            admit["refill"][s] = True
            admit["base"][s] = pos
            seg = prompt[pos:pos + chunk]
            admit["tokens"][s, :len(seg)] = seg
            if pos > 0:
                self.stats.chunk_refills += 1
        # admit fresh requests to free slots
        putback: List[Request] = []
        for s, req in self._take_free():
            start = 0
            prompt = self._eff_prompt(req)
            if self.paged:
                if putback:
                    putback.append(req)   # FIFO: stay behind the
                    continue              # blocked head
                res = self._admit_paged(s, req)
                while res is None:
                    # pool exhausted even after registry eviction:
                    # preempt an eligible victim (strictly later EDF
                    # key — see _pick_victim) or block FIFO
                    v = self._pick_victim(req)
                    if v is None:
                        break
                    self._preempt_slot(v, admit)
                    res = self._admit_paged(s, req)
                if res is None:           # pool exhausted: re-queue
                    putback.append(req)
                    continue
                start = res
                admit["start_pos"][s] = start
                admit["block_table"][s] = self._slot_table_row(s)
            admit["new"][s] = True
            admit["base"][s] = start
            seg = prompt[start:start + chunk]
            admit["tokens"][s, :len(seg)] = seg
            admit["prompt_len"][s] = len(prompt)
            admit["max_new"][s] = self._eff_max_new(req)
            admit["eos"][s] = req.eos_id
            temp, topk, topp = self._req_sampling(req)
            admit["temp"][s] = temp
            admit["top_k"][s] = topk
            admit["top_p"][s] = topp
            self.active[s] = req
            self._slot_prompt[s] = prompt
            self._prefill_pos[s] = start
            if temp > 0.0:
                self._stochastic_slots.add(s)
            self.stats.prefills += 1
            self.stats.inscan_admissions += 1
        if putback:
            self.queue.extendleft(reversed(putback))
        return admit

    def _fill_slots(self) -> Dict[str, np.ndarray]:
        if self.admission == "chunked":
            admit = self._fill_slots_chunked()
        else:
            self._fill_slots_stall()
            admit = self._empty_admit()
        if self._poison_uids:
            for s, r in enumerate(self.active):
                if r is not None and r.uid in self._poison_uids:
                    admit["poison"][s] = True
        return admit

    # -- fused K-token decode + in-scan admission ---------------------------
    def _merge_admissions(self, cache, st: SlotState, admit):
        """Fold the host's admission arrays into the carry, inside the
        megastep jit. Fresh slots get their cache rows zeroed (every
        family's init state is zeros; attention junk past ``lens`` is
        never read) and their SlotState rows rebuilt; chunk refills
        only swap the prompt window."""
        nm = jnp.asarray(admit["new"])
        anym = nm | jnp.asarray(admit["refill"])
        axes = self.model.cache_axes(page_size=self._eff_page)

        def reset(leaf, ax):
            if "batch" not in ax:
                # paged pool leaves have no per-slot rows to zero;
                # stale block contents past ``lens`` are never read
                # (same contract as dense junk past lens)
                return leaf
            b = ax.index("batch")
            m = nm.reshape(tuple(nm.shape[0] if i == b else 1
                                 for i in range(leaf.ndim)))
            return jnp.where(m, jnp.zeros((), leaf.dtype), leaf)

        cache = jax.tree_util.tree_map(reset, cache, axes)
        if self.paged:
            # fresh slots start at their cached-prefix length with the
            # host-allocated block table mapped in
            start = jnp.asarray(admit["start_pos"])
            tbl = jnp.asarray(admit["block_table"])
            lay = dict(cache["layers"])
            lay["lens"] = jnp.where(nm[None, :], start[None, :],
                                    lay["lens"])
            lay["block_table"] = jnp.where(nm[None, :, None], tbl[None],
                                           lay["block_table"])
            cache = dict(cache, layers=lay)
            start_pos = start
        else:
            start_pos = jnp.zeros_like(st.prefill_pos)
        new_state = SlotState(
            last_token=jnp.where(nm, 0, st.last_token),
            gen_len=jnp.where(nm, 0, st.gen_len),
            max_new=jnp.where(nm, admit["max_new"], st.max_new),
            eos_id=jnp.where(nm, admit["eos"], st.eos_id),
            phase=jnp.where(nm, PHASE_PREFILL, st.phase),
            prefill_pos=jnp.where(nm, start_pos, st.prefill_pos),
            prompt_len=jnp.where(nm, admit["prompt_len"], st.prompt_len),
            chunk_base=jnp.where(anym, admit["base"], st.chunk_base),
            prompt_buf=jnp.where(anym[:, None], admit["tokens"],
                                 st.prompt_buf),
            temperature=jnp.where(nm, admit["temp"], st.temperature),
            top_k=jnp.where(nm, admit["top_k"], st.top_k),
            top_p=jnp.where(nm, admit["top_p"], st.top_p),
            rng=st.rng)
        return cache, new_state

    def _megastep_impl(self, params, cache, state, admit, all_greedy):
        """K decode substeps in one ``lax.scan``: admission merge,
        in-jit per-slot sampling, per-slot EOS/length retirement via
        the frozen-write mask. Prefilling slots feed prompt tokens from
        their chunk buffer instead of ``last_token`` and stay silent
        until the last prompt position. ``all_greedy`` (static) traces
        a pure-argmax sampler when no active slot is stochastic.

        Every substep checks per-slot logits for NaN/inf: a nonfinite
        slot emits nothing, is forced to PHASE_IDLE (so subsequent
        substeps freeze its cache writes — the same retirement path EOS
        takes), and is flagged in the packed block's fourth row for the
        host to error-retire. Other slots in the batch are untouched —
        their logits, sampling, and cache writes never see the bad
        slot's values. ``admit["poison"]`` lets the fault injector
        corrupt a slot's logits in-jit to exercise exactly this path.

        Returns (cache, state, block (4, K, slots) = tokens / emitted /
        prefill progress / nonfinite flag)."""
        cache, state = self._merge_admissions(cache, state, admit)
        chunk = self.prefill_chunk
        poison = jnp.asarray(admit["poison"])

        def body(carry, _):
            cache, st = carry
            is_pre = st.phase == PHASE_PREFILL
            is_dec = st.phase == PHASE_DECODE
            off = jnp.clip(st.prefill_pos - st.chunk_base, 0, chunk - 1)
            ptok = jnp.take_along_axis(st.prompt_buf, off[:, None],
                                       axis=1)[:, 0]
            # a prefill slot whose chunk window ran dry idles (cache
            # frozen) until the host refreshes the buffer — can only
            # happen when prefill_chunk < megastep_k
            starved = is_pre & (st.prefill_pos - st.chunk_base >= chunk)
            feeding = is_pre & ~starved
            in_tok = jnp.where(is_pre, ptok, st.last_token)
            advance = feeding | is_dec
            logits, cache = self.model.decode_step(
                params, in_tok[:, None], cache, advance_mask=advance)
            logits = jnp.where(poison[:, None],
                               jnp.full((), jnp.nan, logits.dtype),
                               logits)
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            bad = (is_pre | is_dec) & ~finite
            rng, step_key = jax.random.split(st.rng)
            if all_greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = sample_batched(logits, step_key, st.temperature,
                                     st.top_k, st.top_p)
            finishing = feeding & (st.prefill_pos + 1 >= st.prompt_len)
            emit = (is_dec | finishing) & ~bad
            tok = jnp.where(emit, tok, jnp.int32(PAD_ID))
            gen_len = st.gen_len + emit.astype(jnp.int32)
            done_now = emit & ((tok == st.eos_id) |
                               (gen_len >= st.max_new))
            phase = jnp.where(
                emit, jnp.where(done_now, PHASE_IDLE, PHASE_DECODE),
                st.phase)
            # a nonfinite slot retires through the frozen-write path:
            # IDLE phase means every later substep's advance mask and
            # emit mask exclude it, exactly like EOS
            phase = jnp.where(bad, PHASE_IDLE, phase)
            new_st = dataclasses.replace(
                st,
                last_token=jnp.where(emit, tok, st.last_token),
                gen_len=gen_len,
                phase=phase,
                prefill_pos=st.prefill_pos + feeding.astype(jnp.int32),
                rng=rng)
            return (cache, new_st), (tok, emit, new_st.prefill_pos, bad)

        (cache, state), (toks, emitted, pos, flagged) = jax.lax.scan(
            body, (cache, state), None, length=self.megastep_k,
            unroll=self.megastep_unroll)
        # pack (tokens, emitted, prefill progress, nonfinite flags)
        # into one (4, K, slots) block → a single device→host transfer
        return cache, state, jnp.stack(
            [toks, emitted.astype(jnp.int32), pos,
             flagged.astype(jnp.int32)])

    def _dispatch_megastep(self) -> bool:
        """Dispatch half of the pipelined loop: stage admissions from
        the host's current slot view and launch one megastep. Dispatch
        is asynchronous under JAX — the returned block rides
        ``_inflight`` (with a snapshot of its slot occupants) until
        ``_drain_oldest`` synchronizes on it. Returns False when no
        slot is live in the host view (nothing to launch)."""
        t0 = time.perf_counter()
        admit = self._fill_slots()
        self.stats.stage_wall_s += time.perf_counter() - t0
        if not any(r is not None for r in self.active):
            return False
        self.cache, self.state, block = self._megastep(
            self.params, self.cache, self.state, admit,
            not self._stochastic_slots)
        self._inflight.append((block, tuple(self.active)))
        self.stats.megasteps += 1
        self.stats.steps += self.megastep_k
        return True

    def _drain_oldest(self) -> None:
        """Drain half: block on the oldest in-flight megastep's packed
        token block (the loop's one sync point), then attribute tokens
        and retirements to the requests that occupied the slots *when
        that megastep was dispatched* — under pipelining the host view
        may have moved on (a slot freed by an earlier drain can
        already hold a newer request, whose rows in this older block
        are all idle)."""
        block, occupants = self._inflight.popleft()
        t0 = time.perf_counter()
        block = np.asarray(block)        # ONE host transfer per K tokens
        self.stats.drain_wait_s += time.perf_counter() - t0
        toks, emitted = block[0], block[1].astype(bool)
        last_pos = block[2][-1]
        bad = block[3].astype(bool).any(axis=0)
        for s in range(self.slots):
            # advance the prompt-cursor mirror only while the slot
            # still serves the request this block belongs to — a stale
            # pos row from a retired occupant must never leak into a
            # newer request's chunk-refill base. Nonfinite slots are
            # about to be error-retired: don't advance their mirror or
            # publish their pages to the prefix registry.
            if (occupants[s] is not None and not bad[s]
                    and self.active[s] is occupants[s]):
                self._prefill_pos[s] = int(last_pos[s])
                # prompt fully consumed → its pages now exist on
                # device: publish them to the prefix registry
                if (self.prefix_cache_enabled
                        and not self._slot_reg_done[s]
                        and self._prefill_pos[s]
                        >= len(occupants[s].prompt)):
                    self._register_prefix(s, occupants[s])
        for k in range(toks.shape[0]):
            for s in range(self.slots):
                req = occupants[s]
                if req is None or req.cancelled or not emitted[k, s]:
                    continue
                tok = int(toks[k, s])
                req.output.append(tok)
                self.stats.tokens_generated += 1
                if tok == req.eos_id or len(req.output) >= \
                        req.max_new_tokens:
                    req.done = True      # device already froze this slot
                    if self.active[s] is req:
                        self.active[s] = None
                        self._stochastic_slots.discard(s)
                        self._slot_prompt[s] = None
                        if self.paged:
                            self._release_slot_blocks(s)
        # error-retire slots the device flagged nonfinite: the scan
        # already froze them (no emit, no cache writes past the flag),
        # the host marks the request failed and recycles its slot.
        # Tokens the request emitted *before* the poison landed were
        # appended above — the error reports what it got.
        for s in range(self.slots):
            if not bad[s]:
                continue
            req = occupants[s]
            if req is None or req.done or req.cancelled:
                continue
            req.error = "nonfinite-logits"
            req.done = True
            self.stats.poisoned += 1
            self._poison_uids.discard(req.uid)
            if self.active[s] is req:
                self.active[s] = None
                self._stochastic_slots.discard(s)
                self._slot_prompt[s] = None
                if self.paged:
                    self._release_slot_blocks(s)

    def step(self) -> int:
        """Admit what fits, dispatch one megastep (up to ``megastep_k``
        tokens per decoding slot), and drain the oldest in-flight block
        once ``pipeline_depth`` megasteps are outstanding — at depth 1
        that is the megastep just dispatched (serial); at depth 2 the
        previous one, so its drain and the next admission staging
        overlap the dispatched megastep's device execution. Returns
        #slots still occupied in the host view."""
        t0 = time.perf_counter()
        if self._dispatch_megastep():
            while len(self._inflight) >= max(self.pipeline_depth, 1):
                self._drain_oldest()
        else:
            # nothing live in the host view: flush the pipeline so
            # in-flight retirements land and admission can resume
            while self._inflight:
                self._drain_oldest()
        self.stats.decode_wall_s += time.perf_counter() - t0
        if self.audit_every_step:
            self.audit()
        return sum(r is not None for r in self.active)

    def run(self, max_steps: int = 10000) -> None:
        """Drain queue + active slots (``max_steps`` megasteps)."""
        for _ in range(max_steps):
            if not self.has_work():
                return
            self.step()
