"""Parameter-spec system: shape + logical axes + init, defined once.

Every model family builds a nested dict of :class:`ParamSpec`; from it
we derive (a) initialized arrays, (b) PartitionSpecs for pjit, and
(c) ShapeDtypeStructs for the dry-run — guaranteed consistent because
they come from the same source of truth.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import AxisRules, logical_to_spec
from repro.quant.quantize import QuantizedTensor


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == ndim
    init: str = "normal"              # normal | zeros | ones | small_a
    scale: float = 1.0                # stddev multiplier for normal

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec_tree_leaf(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, rng: jax.Array, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree_util.tree_flatten(
        specs, is_leaf=is_spec_tree_leaf)
    keys = jax.random.split(rng, len(leaves))

    def mk(spec: ParamSpec, key):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        if spec.init == "small_a":
            # RG-LRU recurrence parameter: a = sigmoid(x)^(1/c) near 1;
            # init the underlying logit in a stable range
            return jnp.full(spec.shape, 4.0, dtype)
        if spec.init == "fan_out":
            # embeddings: std 1/sqrt(d_model) so tied unembedding gives
            # O(1) logits
            std = spec.scale * (spec.shape[-1] ** -0.5)
            return (jax.random.normal(key, spec.shape, jnp.float32) * std
                    ).astype(dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale * (fan_in ** -0.5)
        return (jax.random.normal(key, spec.shape, jnp.float32) * std
                ).astype(dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [mk(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs, dtype=jnp.bfloat16):
    """ShapeDtypeStructs — dry-run stand-ins, no allocation."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs, is_leaf=is_spec_tree_leaf)


def param_pspecs(specs, rules: AxisRules, mesh: Optional[Mesh] = None):
    """PartitionSpec tree parallel to the spec tree."""
    return jax.tree_util.tree_map(
        lambda s: logical_to_spec(s.axes, rules, mesh),
        specs, is_leaf=is_spec_tree_leaf)


def param_shardings(specs, rules: AxisRules, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, logical_to_spec(s.axes, rules, mesh)),
        specs, is_leaf=is_spec_tree_leaf)


def match_quantized(tree, params):
    """Expand a per-param tree (specs/shardings) to match a param pytree
    that contains QuantizedTensor nodes.

    For a QuantizedTensor leaf, data and scales reuse the weight's
    entry: their layouts preserve the (K, N) axis order (K possibly
    packed/grouped, which only changes sizes, not axis meaning).
    """
    def walk(entry, p):
        if isinstance(p, QuantizedTensor):
            return QuantizedTensor(data=entry, scales=entry, fmt=p.fmt,
                                   group=p.group)
        if isinstance(p, dict):
            return {k: walk(entry[k], v) for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            return type(p)(walk(e, v) for e, v in zip(entry, p))
        return entry

    return walk(tree, params)


def count_params(params) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        total += leaf.size
    return total
