"""Mamba-2 SSD (state-space duality) layer [arXiv:2405.21060].

TPU adaptation (DESIGN.md §4): the SSD *chunked* algorithm is exactly
the right decomposition for the MXU — intra-chunk work is dense
(Q x Q) matmuls, inter-chunk state propagation is a short sequential
scan of (H, P, N) states. The paper's fused-projection technique maps
to the fused ``in_proj`` (z, x, B, C, dt are five independent GEMMs on
the same normed input → one wide GEMM, logical axis ``qkv_fused``).

Decode is an O(1) state update — this is why mamba2 runs ``long_500k``
natively (no KV cache at all).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec


def ssm_specs(cfg: ModelConfig) -> Dict:
    D, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.ssm_heads
    conv_ch = di + 2 * N
    return {
        "in_proj": {"w": ParamSpec((D, 2 * di + 2 * N + nh),
                                   ("embed", "qkv_fused"))},
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), ("conv", None)),
        "conv_b": ParamSpec((conv_ch,), (None,), init="zeros"),
        "A_log": ParamSpec((nh,), (None,), init="zeros"),
        "dt_bias": ParamSpec((nh,), (None,), init="zeros"),
        "D_skip": ParamSpec((nh,), (None,), init="ones"),
        "norm_w": ParamSpec((di,), (None,), init="ones"),
        "out_proj": {"w": ParamSpec((di, D), ("heads", "embed"))},
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B, S, C); w: (width, C).

    ``state``: (B, width-1, C) past inputs (decode). Returns
    (y, new_state)."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros(x.shape[:1] + (width - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(width))
    new_state = xp[:, -(width - 1):]
    return jax.nn.silu(y + b.astype(x.dtype)), new_state


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None,
                unroll: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (positive); A: (H,) negative;
    Bm, Cm: (B,S,N). Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    # chunk-major layout for the scan: (nc, B, Q, ...)
    xf = jnp.moveaxis(x.astype(jnp.float32).reshape(Bb, nc, Q, H, P), 1, 0)
    dtf = jnp.moveaxis(dt.astype(jnp.float32).reshape(Bb, nc, Q, H), 1, 0)
    Bf = jnp.moveaxis(Bm.astype(jnp.float32).reshape(Bb, nc, Q, N), 1, 0)
    Cf = jnp.moveaxis(Cm.astype(jnp.float32).reshape(Bb, nc, Q, N), 1, 0)
    Af = A.astype(jnp.float32)
    tril = jnp.tril(jnp.ones((Q, Q), bool))

    S0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s_prev, inp):
        x_c, dt_c, B_c, C_c = inp          # (B,Q,H,P),(B,Q,H),(B,Q,N)x2
        a = dt_c * Af                      # (B,Q,H) <= 0
        L = jnp.cumsum(a, axis=1)          # within-chunk log decay
        # intra-chunk (dense, MXU-friendly): one (Q,Q) matmul per head
        CB = jnp.einsum("bqn,bkn->bqk", C_c, B_c)      # (B,Q,Q)
        diff = L[:, :, None, :] - L[:, None, :, :]     # (B,Q,Q,H)
        M = jnp.where(tril[None, :, :, None], jnp.exp(diff), 0.0)
        M = M * (CB[..., None] * dt_c[:, None, :, :])  # (B,Q,Q,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", M, x_c)
        # chunk state contribution
        decay_to_end = jnp.exp(L[:, -1:, :] - L)       # (B,Q,H)
        S_c = jnp.einsum("bqh,bqn,bqhp->bhpn",
                         decay_to_end * dt_c, B_c, x_c)
        # inter-chunk contribution from the carried state
        y_inter = jnp.einsum("bqn,bhpn->bqhp", C_c, s_prev)
        y_inter = y_inter * jnp.exp(L)[..., None]
        gamma = jnp.exp(L[:, -1])                      # (B,H)
        s_new = s_prev * gamma[..., None, None] + S_c
        return s_new, (y_intra + y_inter)

    final, ys = jax.lax.scan(step, S0, (xf, dtf, Bf, Cf), unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)    # (B,S,H,P)
    return y.astype(x.dtype), final


def ssm_forward(p, cfg: ModelConfig, x: jax.Array,
                conv_state: Optional[jax.Array] = None,
                ssd_state: Optional[jax.Array] = None,
                return_state: bool = False):
    """Full-sequence SSD layer. x: (B, S, D)."""
    B, S, D = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = layers.linear(p["in_proj"], x, use_pallas=cfg.use_pallas)
    zxbcdt = constrain(zxbcdt, ("batch", None, "qkv_fused"))
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :di].reshape(B, S, H, P)
    Bm = xbc[..., di:di + N]
    Cm = xbc[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, final_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk,
                                 init_state=ssd_state,
                                 unroll=cfg.unroll_scans)
    y = y + xs.astype(y.dtype) * p["D_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = layers.linear(p["out_proj"], y, use_pallas=cfg.use_pallas)
    if return_state:
        return out, (new_conv, final_state)
    return out


def ssm_decode(p, cfg: ModelConfig, x: jax.Array, cache: Dict
               ) -> Tuple[jax.Array, Dict]:
    """O(1) single-token state update. x: (B, 1, D)."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = layers.linear(p["in_proj"], x, use_pallas=cfg.use_pallas)
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                 cache["conv"])
    xs = xbc[:, 0, :di].reshape(B, H, P)
    Bm = xbc[:, 0, di:di + N]
    Cm = xbc[:, 0, di + N:]
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))   # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    S_prev = cache["state"].astype(jnp.float32)                # (B,H,P,N)
    decay = jnp.exp(dt1 * A[None])                             # (B,H)
    dBx = jnp.einsum("bh,bhp,bn->bhpn", dt1, xs.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    S_new = S_prev * decay[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", S_new, Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = layers.rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = layers.linear(p["out_proj"], y, use_pallas=cfg.use_pallas)
    new_cache = dict(cache, conv=new_conv,
                     state=S_new.astype(cache["state"].dtype),
                     lens=cache["lens"] + 1)
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di, N = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, N),
                           jnp.float32),
        "lens": jnp.zeros((batch,), jnp.int32),
    }


def ssm_cache_axes() -> Dict:
    return {"conv": ("batch", None, "qkv_fused"),
            "state": ("batch", "heads", None, None),
            "lens": ("batch",)}
