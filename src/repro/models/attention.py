"""GQA attention block: fused QKV, RoPE, chunked flash-style attention,
KV cache (full, or ring-buffer for sliding-window archs).

Two attention paths:

- ``chunked_attention`` — pure-jnp online-softmax over KV blocks with a
  static Python loop over Q blocks. GSPMD partitions it transparently
  (batch/heads/seq shardable), it never materializes the (Sq, Skv)
  score matrix, and causal/window *block skipping* is static — q-chunk i
  only scans KV blocks it can see, making windowed prefill linear. This
  is the default path and what the dry-run lowers.
- Pallas ``flash_attention`` / ``decode_attention`` (kernels/) — the
  TPU hot path, selected by ``cfg.use_pallas`` for single-shard or
  shard_map execution; validated against the same oracle.

The fused-QKV projection is the paper's V1 graph-parallelism realized
as one wide GEMM (DESIGN.md §2).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models import layers
from repro.models.params import ParamSpec
from repro.quant.quantize import (dequantize_rows, kv_group_size,
                                  quantize_rows)


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, cross: bool = False) -> Dict:
    D, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    specs: Dict = {}
    if cfg.fuse_qkv and not cross:
        specs["wqkv"] = layers.linear_spec(
            D, qd + 2 * kvd, ("embed", "qkv_fused"), bias=cfg.qkv_bias,
            bias_axis="qkv_fused")
    else:
        specs["wq"] = layers.linear_spec(D, qd, ("embed", "heads"),
                                         bias=cfg.qkv_bias,
                                         bias_axis="heads")
        specs["wk"] = layers.linear_spec(D, kvd, ("embed", "heads"),
                                         bias=cfg.qkv_bias,
                                         bias_axis="heads")
        specs["wv"] = layers.linear_spec(D, kvd, ("embed", "heads"),
                                         bias=cfg.qkv_bias,
                                         bias_axis="heads")
    specs["wo"] = layers.linear_spec(qd, D, ("heads", "embed"))
    return specs


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (pure jnp, shardable)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      q_offset: int = 0, bq: int = 512, bk: int = 512,
                      scale: Optional[float] = None,
                      unroll: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) → (B, Hq, Sq, D).

    Static Q-chunk loop with per-chunk static KV bounds (causal/window
    block skip); inner lax.scan over KV chunks with online softmax.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    while Sq % bq:
        bq //= 2
    bk = min(bk, Skv)
    while Skv % bk:
        bk //= 2

    # NB: no astype(f32) on k/v — an elementwise convert of the whole
    # cache gets hoisted out of scan-over-layers loops by XLA, doubling
    # HBM. Mixed matmuls with preferred_element_type keep reads at bf16.
    qg = ((q.astype(jnp.float32) * scale).astype(q.dtype)
          .reshape(B, Hkv, G, Sq, D))
    kf = k
    vf = v

    outs = []
    for i in range(Sq // bq):
        q_i = qg[:, :, :, i * bq:(i + 1) * bq]       # (B,Hkv,G,bq,D)
        q_lo = i * bq + q_offset
        q_hi = q_lo + bq - 1
        # static KV bounds for this q chunk
        hi = min(Skv, q_hi + 1) if causal else Skv
        lo = max(0, q_lo - window + 1) if window else 0
        lo_b = (lo // bk) * bk
        hi_b = min(Skv, ((hi + bk - 1) // bk) * bk)
        n_blk = (hi_b - lo_b) // bk
        k_i = kf[:, :, lo_b:hi_b].reshape(B, Hkv, n_blk, bk, D)
        v_i = vf[:, :, lo_b:hi_b].reshape(B, Hkv, n_blk, bk, D)
        k_i = jnp.moveaxis(k_i, 2, 0)                # (n_blk,B,Hkv,bk,D)
        v_i = jnp.moveaxis(v_i, 2, 0)

        qpos = q_lo + jnp.arange(bq)

        def step(carry, inp):
            m, l, acc = carry
            k_c, v_c, blk = inp
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_c,
                           preferred_element_type=jnp.float32)
            kpos = lo_b + blk * bk + jnp.arange(bk)
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, -1))
            p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, -1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(v_c.dtype), v_c,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, bq), jnp.float32),
                jnp.zeros((B, Hkv, G, bq, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            step, init, (k_i, v_i, jnp.arange(n_blk)), unroll=unroll)
        l = jnp.where(l == 0.0, 1.0, l)
        outs.append(acc / l[..., None])

    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block forward
# ---------------------------------------------------------------------------

def _split_qkv(cfg: ModelConfig, p, x, use_pallas: bool):
    qd, kvd = cfg.q_dim, cfg.kv_dim
    if "wqkv" in p:
        qkv = layers.linear(p["wqkv"], x, use_pallas=use_pallas)
        qkv = constrain(qkv, ("batch", None, "qkv_fused"))
        q = qkv[..., :qd]
        k = qkv[..., qd:qd + kvd]
        v = qkv[..., qd + kvd:]
    else:
        q = layers.linear(p["wq"], x, use_pallas=use_pallas)
        k = layers.linear(p["wk"], x, use_pallas=use_pallas)
        v = layers.linear(p["wv"], x, use_pallas=use_pallas)
    return q, k, v


def attention_forward(p, cfg: ModelConfig, x: jax.Array, *,
                      positions: jax.Array, window: int = 0,
                      kv_override: Optional[Tuple] = None,
                      use_rope: bool = True,
                      return_kv: bool = False):
    """Full-sequence attention (training / prefill).

    x: (B, S, D_model); positions: (B, S) absolute positions.
    ``kv_override``: (k, v) in (B, Hkv, Skv, D) — cross-attention.
    ``return_kv``: also return the (roped) K/V for cache fill.
    """
    B, S, _ = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _split_qkv(cfg, p, x, cfg.use_pallas)
    q = q.reshape(B, S, H, hd)
    if kv_override is None:
        k = k.reshape(B, S, Hkv, hd)
        v = v.reshape(B, S, Hkv, hd)
        if use_rope:
            q = layers.apply_rope(q, positions, cfg.rope_theta)
            k = layers.apply_rope(k, positions, cfg.rope_theta)
        k = jnp.swapaxes(k, 1, 2)
        v = jnp.swapaxes(v, 1, 2)
        causal = True
    else:
        k, v = kv_override
        causal = False
    q = jnp.swapaxes(q, 1, 2)                    # (B, H, S, hd)
    q = constrain(q, ("batch", "heads", None, None))
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_offset=0, bq=cfg.attn_block,
                            bk=cfg.attn_block, unroll=cfg.unroll_scans)
    out = jnp.swapaxes(out, 1, 2).reshape(B, S, H * hd)
    out = constrain(out, ("batch", None, "heads"))
    out = layers.linear(p["wo"], out, use_pallas=cfg.use_pallas)
    if return_kv:
        return out, k, v
    return out


def attention_decode(p, cfg: ModelConfig, x: jax.Array, cache: Dict, *,
                     window: int = 0,
                     kv_override: Optional[Tuple] = None,
                     use_rope: bool = True,
                     write_mask: Optional[jax.Array] = None,
                     ) -> Tuple[jax.Array, Dict]:
    """One-token decode with functional cache update.

    x: (B, 1, D); cache: {"k": (B,Hkv,S,hd), "v": ..., "lens": (B,)}.
    ``lens`` counts tokens already in the cache; the new token is
    written at slot ``lens % S`` (ring buffer when the cache is a
    sliding window). Paged caches (``"block_table"`` present) route the
    write through the slot's block table instead; ``write_mask`` (B,)
    bool redirects non-advancing rows' writes to the reserved garbage
    block — paged pools have no per-slot batch axis, so the frozen-write
    select that protects dense caches cannot be applied after the fact.
    """
    B = x.shape[0]
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _split_qkv(cfg, p, x, cfg.use_pallas)
    q = q.reshape(B, H, hd)

    if kv_override is not None:
        k_all, v_all = kv_override
        q = constrain(q, ("batch", "heads", None))
        out = ops.decode_attention(
            q, k_all, v_all, kv_len=cache["cross_lens"],
            use_pallas=cfg.use_pallas)
        out = out.reshape(B, 1, H * hd)
        return layers.linear(p["wo"], out, use_pallas=cfg.use_pallas), cache

    lens = cache["lens"]                          # (B,) int32
    paged = "block_table" in cache
    S_cache = (cache["block_table"].shape[1] * cache["k"].shape[2]
               if paged else cache["k"].shape[2])
    kv_quant = cfg.kv_quant if "k_scale" in cache else "bf16"
    pos = lens                                    # new token's position
    if use_rope:
        # pin the rope operands before the cos/sin broadcast-mul: on big
        # fake-device meshes GSPMD otherwise picks a degenerate sharding
        # for the broadcast (model axis onto the hd/2 dim) and dies with
        # an involuntary-full-rematerialization error
        q = constrain(q, ("batch", "heads", None))
        k = constrain(k.reshape(B, Hkv, hd), ("batch", None, None))
        # q (B,H,hd) → (B,1,H,hd) with positions (B,1)
        q = layers.apply_rope(q[:, None], pos[:, None],
                              cfg.rope_theta)[:, 0]
        k = layers.apply_rope(k[:, None], pos[:, None],
                              cfg.rope_theta)[:, 0]
    else:
        k = k.reshape(B, Hkv, hd)
    v = v.reshape(B, Hkv, hd)
    slot = lens % S_cache
    new_cache = dict(cache, lens=lens + 1)
    new_cache.update(kv_cache_write(cache, k, v, slot,
                                    kv_quant=kv_quant,
                                    group=cfg.quant_group,
                                    write_mask=write_mask))
    kv_len = jnp.minimum(lens + 1, S_cache)
    q = constrain(q, ("batch", "heads", None))
    if kv_quant in ("q8_0", "q4_0"):
        # Fused-dequant path: hand the raw int8 payload + scale leaves
        # to the kernel layer. Under kernels="pallas" the dequant runs
        # in-register inside the block loop (no per-token full-cache
        # unpack); the XLA fallback inside decode_attention_quant is
        # computation-identical to the old kv_cache_read route. Paged
        # pools gather into the dense (B,Hkv,S,·) kernel-entry shape
        # first — positions past kv_len hold garbage-block junk that
        # the kernels' kpos < kv_len mask never reads.
        if paged:
            tbl = cache["block_table"]
            k_q = paged_gather(new_cache["k"], tbl)
            v_q = paged_gather(new_cache["v"], tbl)
            k_s = paged_gather(new_cache["k_scale"], tbl)
            v_s = paged_gather(new_cache["v_scale"], tbl)
        else:
            k_q, v_q = new_cache["k"], new_cache["v"]
            k_s, v_s = new_cache["k_scale"], new_cache["v_scale"]
        out = ops.decode_attention_quant(
            q, k_q, k_s, v_q, v_s, kv_len=kv_len,
            fmt=kv_quant, use_pallas=cfg.use_pallas)
    else:
        k_read, v_read = kv_cache_read(new_cache, kv_quant=kv_quant)
        out = ops.decode_attention(q, k_read, v_read, kv_len=kv_len,
                                   use_pallas=cfg.use_pallas)
    out = out.reshape(B, 1, H * hd)
    out = layers.linear(p["wo"], out, use_pallas=cfg.use_pallas)
    return out, new_cache


def kv_cache_write(cache: Dict, k: jax.Array, v: jax.Array,
                   slot: jax.Array, *, kv_quant: str = "bf16",
                   group: int = 32,
                   write_mask: Optional[jax.Array] = None) -> Dict:
    """Write one (B, Hkv, hd) K/V row at per-row ring ``slot`` (B,).

    Quantized caches (``kv_quant`` q8_0/q4_0) quantize the row at the
    write point — int8 payload into ``k``/``v``, per-(head, group)
    scales into the sibling ``k_scale``/``v_scale`` leaves — so the
    cache stream shrinks to bits/16 of its bf16 footprint. Returns the
    updated leaves only (caller merges + advances ``lens``).

    Paged caches scatter through the slot's row of ``block_table``:
    position ``slot`` lands in page ``slot // P`` at in-page offset
    ``slot % P`` of the pool block that table entry names. Rows with
    ``write_mask`` False are redirected to the reserved garbage block 0
    (paged pools cannot be row-selected after the fact like dense
    caches, so freezing must happen at the write point). Dense caches
    ignore ``write_mask`` — the caller's post-write select handles it."""
    B = k.shape[0]
    if "block_table" in cache:
        P = cache["k"].shape[2]
        bidx = jnp.arange(B)
        blk = cache["block_table"][bidx, slot // P]
        if write_mask is not None:
            blk = jnp.where(write_mask, blk, 0)
        off = slot % P
        if kv_quant in ("bf16", "f16", "f32"):
            return {
                "k": cache["k"].at[blk, :, off].set(
                    k.astype(cache["k"].dtype)),
                "v": cache["v"].at[blk, :, off].set(
                    v.astype(cache["v"].dtype)),
            }
        kq, ks = quantize_rows(k, kv_quant, group)
        vq, vs = quantize_rows(v, kv_quant, group)
        return {
            "k": cache["k"].at[blk, :, off].set(kq),
            "v": cache["v"].at[blk, :, off].set(vq),
            "k_scale": cache["k_scale"].at[blk, :, off].set(
                ks.astype(cache["k_scale"].dtype)),
            "v_scale": cache["v_scale"].at[blk, :, off].set(
                vs.astype(cache["v_scale"].dtype)),
        }
    bidx = jnp.arange(B)
    if kv_quant in ("bf16", "f16", "f32"):
        return {
            "k": cache["k"].at[bidx, :, slot].set(
                k.astype(cache["k"].dtype)),
            "v": cache["v"].at[bidx, :, slot].set(
                v.astype(cache["v"].dtype)),
        }
    kq, ks = quantize_rows(k, kv_quant, group)
    vq, vs = quantize_rows(v, kv_quant, group)
    return {
        "k": cache["k"].at[bidx, :, slot].set(kq),
        "v": cache["v"].at[bidx, :, slot].set(vq),
        "k_scale": cache["k_scale"].at[bidx, :, slot].set(
            ks.astype(cache["k_scale"].dtype)),
        "v_scale": cache["v_scale"].at[bidx, :, slot].set(
            vs.astype(cache["v_scale"].dtype)),
    }


def paged_gather(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Gather a block pool into its dense per-slot view.

    pool: (num_blocks, Hkv, P, d); table: (B, n_pages) int32 →
    (B, Hkv, n_pages * P, d). Unmapped table entries point at the
    garbage block 0; callers mask those positions via kv_len."""
    B, n_pages = table.shape
    _, Hkv, P, d = pool.shape
    g = pool[table]                       # (B, n_pages, Hkv, P, d)
    return jnp.moveaxis(g, 1, 2).reshape(B, Hkv, n_pages * P, d)


def kv_cache_read(cache: Dict, *, kv_quant: str = "bf16",
                  dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """The attention-visible (B, Hkv, S, hd) K/V view of a cache.

    bf16 caches return their leaves as-is; quantized caches dequantize
    payload × scales at the read point, materializing a bf16 view.
    The decode hot path no longer uses this for quantized caches —
    ``attention_decode`` hands the raw leaves to
    ``ops.decode_attention_quant`` (in-VMEM dequant under
    kernels="pallas"); this helper remains for tests and offline
    inspection of cache contents. Paged caches gather their pools
    through the block table first, so the returned view is
    shape-identical to a dense cache's."""
    if "block_table" in cache:
        tbl = cache["block_table"]
        k = paged_gather(cache["k"], tbl)
        v = paged_gather(cache["v"], tbl)
        if kv_quant in ("bf16", "f16", "f32"):
            return k, v
        return (dequantize_rows(k, paged_gather(cache["k_scale"], tbl),
                                kv_quant, dtype),
                dequantize_rows(v, paged_gather(cache["v_scale"], tbl),
                                kv_quant, dtype))
    if kv_quant in ("bf16", "f16", "f32"):
        return cache["k"], cache["v"]
    return (dequantize_rows(cache["k"], cache["k_scale"], kv_quant, dtype),
            dequantize_rows(cache["v"], cache["v_scale"], kv_quant, dtype))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: int = 0, dtype=jnp.bfloat16,
                  kv_quant: str = "bf16", page_size: int = 0,
                  num_blocks: int = 0) -> Dict:
    """Cache shapes; ``window`` > 0 caps the cache (ring buffer).

    ``kv_quant`` q8_0/q4_0 stores K/V as int8 payload (q4_0
    nibble-packed along head_dim) plus groupwise ``k_scale``/``v_scale``
    leaves — every leaf still carries batch on axis 0 and the ring
    position on axis 2, so the frozen-write mask, megastep donation and
    prefill splicing treat them like any other cache leaf.

    ``page_size`` > 0 pages the cache instead: K/V (and scale) leaves
    become block *pools* of shape (num_blocks, Hkv, page_size, ·) with
    no batch axis, and a per-slot ``block_table`` (batch, max_pages)
    int32 leaf maps logical pages onto pool blocks. Block 0 is reserved
    as the garbage block (frozen-row writes and unmapped table entries
    land there); ``num_blocks`` defaults to one block per logical page
    per slot plus the garbage block — capacity-equivalent to dense —
    but can be set lower so total memory tracks live tokens. Paging
    requires full attention (``window == 0``) and ``page_size`` dividing
    the sequence capacity so the gathered view is shape-identical to a
    dense cache."""
    S = min(max_len, window) if window else max_len
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    quantized = kv_quant not in ("bf16", "f16", "f32")
    if quantized:
        g = kv_group_size(hd, cfg.quant_group, kv_quant)
        pd = hd // 2 if kv_quant == "q4_0" else hd
    if page_size:
        if window:
            raise ValueError(
                "paged KV cache requires full attention (window == 0); "
                f"got window={window}")
        if S % page_size:
            raise ValueError(
                f"page_size {page_size} must divide the cache length {S}")
        max_pages = S // page_size
        n_blocks = num_blocks if num_blocks else batch * max_pages + 1
        cache = {
            "k": jnp.zeros((n_blocks, Hkv, page_size,
                            pd if quantized else hd),
                           jnp.int8 if quantized else dtype),
            "v": jnp.zeros((n_blocks, Hkv, page_size,
                            pd if quantized else hd),
                           jnp.int8 if quantized else dtype),
            "block_table": jnp.zeros((batch, max_pages), jnp.int32),
            "lens": jnp.zeros((batch,), jnp.int32),
        }
        if quantized:
            cache["k_scale"] = jnp.zeros(
                (n_blocks, Hkv, page_size, hd // g), dtype)
            cache["v_scale"] = jnp.zeros(
                (n_blocks, Hkv, page_size, hd // g), dtype)
        return cache
    if not quantized:
        return {
            "k": jnp.zeros((batch, Hkv, S, hd), dtype),
            "v": jnp.zeros((batch, Hkv, S, hd), dtype),
            "lens": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, Hkv, S, pd), jnp.int8),
        "v": jnp.zeros((batch, Hkv, S, pd), jnp.int8),
        "k_scale": jnp.zeros((batch, Hkv, S, hd // g), dtype),
        "v_scale": jnp.zeros((batch, Hkv, S, hd // g), dtype),
        "lens": jnp.zeros((batch,), jnp.int32),
    }


def kv_cache_axes(kv_quant: str = "bf16", paged: bool = False) -> Dict:
    if paged:
        # pool leaves carry the block id on axis 0 — deliberately NOT
        # "batch": splice/merge/freeze machinery keys on the "batch"
        # axis name and must leave pools untouched.
        pool = ("kv_block", None, "kv_page", None)
        axes = {"k": pool, "v": pool,
                "block_table": ("batch", None),
                "lens": ("batch",)}
        if kv_quant not in ("bf16", "f16", "f32"):
            axes["k_scale"] = pool
            axes["v_scale"] = pool
        return axes
    axes = {"k": ("batch", None, "kv_seq", None),
            "v": ("batch", None, "kv_seq", None),
            "lens": ("batch",)}
    if kv_quant not in ("bf16", "f16", "f32"):
        axes["k_scale"] = ("batch", None, "kv_seq", None)
        axes["v_scale"] = ("batch", None, "kv_seq", None)
    return axes
