"""Shared building blocks: norms, RoPE, embeddings, (fused) projections.

The fused projections are the paper's §7 technique in TPU form: the
independent GEMM sets found by ``core/scheduler.find_concurrent_gemms``
({Q,K,V}, {ffn_gate, ffn_up}, the SSD in_proj pieces) become single wide
matmuls — one MXU launch instead of three, one weight stream instead of
three strided ones.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.kernels import ops
from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, weight, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., H, D) with positions (..., S) or (...,)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]              # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    specs = {"embedding": ParamSpec((cfg.padded_vocab, cfg.d_model),
                                    ("vocab", "embed"), init="fan_out")}
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.padded_vocab),
                                     ("embed", "vocab"))
    return specs


def embed(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embedding"].T
    else:
        w = params["lm_head"]
    logits = ops.matmul(x, w, out_dtype=jnp.float32)
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# Linear / fused projections
# ---------------------------------------------------------------------------

def linear_spec(d_in: int, d_out: int, axes: Tuple[Optional[str], ...],
                bias: bool = False, bias_axis: Optional[str] = None):
    out = {"w": ParamSpec((d_in, d_out), axes)}
    if bias:
        out["b"] = ParamSpec((d_out,), (bias_axis,), init="zeros")
    return out


def linear(p, x: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    y = ops.matmul(x, p["w"], use_pallas=use_pallas)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def gelu(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU with the tanh written as 2σ(2z)−1.

    ``jax.nn.gelu`` lowers to a ``tanh`` HLO, which this container's
    XLA cannot partition under SPMD (``UNIMPLEMENTED: tanh`` on the
    multi-pod mesh). The logistic form is mathematically identical,
    numerically stable in both tails, and partitions fine (``silu``
    archs already compile through the same lowering).
    """
    xf = x.astype(jnp.float32)
    z = 0.7978845608028654 * (xf + 0.044715 * xf * xf * xf)
    t = 2.0 * jax.nn.sigmoid(2.0 * z) - 1.0
    return (0.5 * xf * (1.0 + t)).astype(x.dtype)


def activation_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": gelu,
            "relu": jax.nn.relu}[name]
