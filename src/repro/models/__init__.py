from repro.models.model import Model, input_specs

__all__ = ["Model", "input_specs"]
