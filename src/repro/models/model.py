"""Unified model: every assigned family behind one API.

``Model`` exposes:
- ``param_specs() / init(rng) / abstract_params()`` — one source of truth
  for shapes, logical sharding axes and dry-run stand-ins.
- ``forward(params, batch)`` — full-sequence logits (training).
- ``loss(params, batch)`` — next-token CE (+ MoE aux loss).
- ``prefill(params, batch, cache)`` — process the prompt, fill caches,
  return last-position logits.
- ``decode_step(params, tokens, cache)`` — one token for the whole
  batch (the paper's decode phase; what ``decode_32k``/``long_500k``
  dry-runs lower).
- ``init_cache(batch, seq_len) / cache_axes()`` — per-family cache
  pytrees (KV ring buffers, SSD states, RG-LRU states, cross-attn KV).

Homogeneous stacks (dense/moe/ssm/vlm/audio) scan over stacked layer
params; the hybrid 1:2 pattern uses a python loop (26 layers).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import hybrid as hy
from repro.models import layers
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.params import (
    ParamSpec, abstract_params, init_params, is_spec_tree_leaf)
from repro.quant.quantize import quantize_tree


# ---------------------------------------------------------------------------
# Spec helpers
# ---------------------------------------------------------------------------

def stack_specs(n: int, specs):
    """Add a leading layer dim to every spec (for scan-over-layers)."""
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, (None,) + s.axes, s.init,
                            s.scale),
        specs, is_leaf=is_spec_tree_leaf)


def _norm_spec(d):
    return ParamSpec((d,), (None,), init="ones")


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameter tree ---------------------------------------------------
    def _attn_layer_specs(self, cross: bool = False) -> Dict:
        cfg = self.cfg
        s = {
            "attn_norm": _norm_spec(cfg.d_model),
            "attn": attn.attention_specs(cfg),
            "ffn_norm": _norm_spec(cfg.d_model),
        }
        if cross:
            s["cross_norm"] = _norm_spec(cfg.d_model)
            s["cross"] = attn.attention_specs(cfg, cross=True)
        if cfg.is_moe:
            s["moe"] = moe_mod.moe_specs(cfg)
        else:
            s["mlp"] = mlp_mod.mlp_specs(cfg)
        return s

    def _ssm_layer_specs(self) -> Dict:
        return {"norm": _norm_spec(self.cfg.d_model),
                "ssm": ssm_mod.ssm_specs(self.cfg)}

    def _hybrid_layer_specs(self, kind: str) -> Dict:
        cfg = self.cfg
        if kind == "rglru":
            temporal = {"rglru": hy.rglru_specs(cfg)}
        else:
            temporal = {"attn": attn.attention_specs(cfg)}
        return {"attn_norm": _norm_spec(cfg.d_model), **temporal,
                "ffn_norm": _norm_spec(cfg.d_model),
                "mlp": mlp_mod.mlp_specs(cfg)}

    def param_specs(self) -> Dict:
        cfg = self.cfg
        specs: Dict[str, Any] = layers.embed_specs(cfg)
        specs["final_norm"] = _norm_spec(cfg.d_model)
        if cfg.arch_type in ("dense", "moe", "vlm"):
            specs["layers"] = stack_specs(cfg.num_layers,
                                          self._attn_layer_specs())
        elif cfg.arch_type == "ssm":
            specs["layers"] = stack_specs(cfg.num_layers,
                                          self._ssm_layer_specs())
        elif cfg.arch_type == "hybrid":
            specs["layers"] = [self._hybrid_layer_specs(k)
                               for k in cfg.layer_pattern()]
        elif cfg.arch_type == "audio":
            specs["encoder"] = stack_specs(
                cfg.num_encoder_layers, self._enc_layer_specs())
            specs["enc_norm"] = _norm_spec(cfg.d_model)
            specs["layers"] = stack_specs(
                cfg.num_layers, self._attn_layer_specs(cross=True))
        else:
            raise ValueError(cfg.arch_type)
        return specs

    def _enc_layer_specs(self) -> Dict:
        cfg = self.cfg
        return {"attn_norm": _norm_spec(cfg.d_model),
                "attn": attn.attention_specs(cfg),
                "ffn_norm": _norm_spec(cfg.d_model),
                "mlp": mlp_mod.mlp_specs(cfg)}

    def init(self, rng: jax.Array, quantize: Optional[bool] = None):
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.param_dtype == "bf16" else jnp.float32
        params = init_params(self.param_specs(), rng, dtype)
        do_quant = (cfg.quant_policy not in ("bf16", "f16", "f32")
                    if quantize is None else quantize)
        if do_quant:
            params = quantize_tree(params, cfg.quant_policy,
                                   cfg.quant_group)
        return params

    def abstract_params(self):
        cfg = self.cfg
        dtype = jnp.bfloat16 if cfg.param_dtype == "bf16" else jnp.float32
        abs_tree = abstract_params(self.param_specs(), dtype)
        if cfg.quant_policy not in ("bf16", "f16", "f32"):
            # quantized stand-ins so the dry-run sees int4/int8 storage
            abs_tree = jax.eval_shape(
                lambda p: quantize_tree(p, cfg.quant_policy,
                                        cfg.quant_group), abs_tree)
        return abs_tree

    # -- windowing policy ---------------------------------------------------
    def window_for(self, total_len: int, kind: str = "attn") -> int:
        cfg = self.cfg
        if cfg.arch_type == "hybrid":
            return cfg.local_attn_window
        if cfg.sliding_window:
            return cfg.sliding_window
        if total_len > cfg.max_full_attn:
            return cfg.window_long_ctx   # long-context fallback (DESIGN §4)
        return 0

    # -- blocks --------------------------------------------------------------
    def _attn_block(self, p, x, positions, window, enc_out=None):
        cfg = self.cfg
        h = layers.rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        h = attn.attention_forward(p["attn"], cfg, h, positions=positions,
                                   window=window)
        x = x + h
        if enc_out is not None and "cross" in p:
            h = layers.rmsnorm(x, p["cross_norm"], cfg.norm_eps)
            k, v = self._cross_kv(p["cross"], enc_out)
            h = attn.attention_forward(p["cross"], cfg, h,
                                       positions=positions,
                                       kv_override=(k, v), use_rope=False)
            x = x + h
        h = layers.rmsnorm(x, p["ffn_norm"], cfg.norm_eps)
        aux = 0.0
        if cfg.is_moe:
            h, aux = moe_mod.moe_forward(p["moe"], cfg, h)
        else:
            h = mlp_mod.mlp_forward(p["mlp"], cfg, h)
        x = x + h
        x = constrain(x, ("batch", "seq", "act_embed"))
        return x, aux

    def _cross_kv(self, p, enc_out):
        cfg = self.cfg
        B, S_enc, _ = enc_out.shape
        k = layers.linear(p["wk"], enc_out).reshape(
            B, S_enc, cfg.num_kv_heads, cfg.head_dim).swapaxes(1, 2)
        v = layers.linear(p["wv"], enc_out).reshape(
            B, S_enc, cfg.num_kv_heads, cfg.head_dim).swapaxes(1, 2)
        return k, v

    def _run_stack(self, params_layers, x, positions, window,
                   enc_out=None):
        cfg = self.cfg

        def body(carry, p_l):
            h, aux = carry
            h, a = self._attn_block(p_l, h, positions, window, enc_out)
            return (h, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = _layer_scan(body_fn, (x, 0.0), params_layers,
                                  cfg.unroll_scans)
        return x, aux

    # -- encoder (audio) -------------------------------------------------------
    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(jnp.bfloat16 if cfg.dtype == "bf16"
                          else jnp.float32)
        x = constrain(x, ("batch", "seq", "act_embed"))
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     x.shape[:2])

        def body(carry, p_l):
            h = carry
            z = layers.rmsnorm(h, p_l["attn_norm"], cfg.norm_eps)
            # bidirectional self-attention
            z = attn.attention_forward(p_l["attn"], cfg, z,
                                       positions=positions,
                                       kv_override=None, use_rope=True)
            h = h + z
            z = layers.rmsnorm(h, p_l["ffn_norm"], cfg.norm_eps)
            h = h + mlp_mod.mlp_forward(p_l["mlp"], cfg, z)
            return h, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = _layer_scan(body_fn, x, params["encoder"],
                           cfg.unroll_scans)
        return layers.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    # -- full-sequence forward (training / prefill core) -----------------------
    def forward(self, params, batch: Dict,
                return_hidden: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = layers.embed(params, tokens)
        prefix_len = 0
        if cfg.arch_type == "vlm":
            x, prefix_len = _prepend_prefix(batch["prefix"], x)
        x = constrain(x, ("batch", "seq", "act_embed"))
        total = S + prefix_len
        positions = jnp.broadcast_to(jnp.arange(total), (B, total))
        window = self.window_for(total)
        enc_out = None
        if cfg.arch_type == "audio":
            enc_out = self._encode(params, batch["frames"])

        aux = 0.0
        if cfg.arch_type == "hybrid":
            for p_l, kind in zip(params["layers"], cfg.layer_pattern()):
                x = self._hybrid_block(p_l, kind, x, positions)
        elif cfg.arch_type == "ssm":
            def body(carry, p_l):
                h = carry
                z = layers.rmsnorm(h, p_l["norm"], cfg.norm_eps)
                h = h + ssm_mod.ssm_forward(p_l["ssm"], cfg, z)
                h = constrain(h, ("batch", "seq", "act_embed"))
                return h, None
            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = _layer_scan(body_fn, x, params["layers"],
                               cfg.unroll_scans)
        else:
            x, aux = self._run_stack(params["layers"], x, positions,
                                     window, enc_out)
        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if prefix_len:
            x = x[:, prefix_len:]
        if return_hidden:
            return x, aux
        logits = layers.unembed(params, x, cfg)
        return logits, aux

    def _hybrid_block(self, p_l, kind, x, positions):
        cfg = self.cfg
        h = layers.rmsnorm(x, p_l["attn_norm"], cfg.norm_eps)
        if kind == "rglru":
            h = hy.rglru_forward(p_l["rglru"], cfg, h)
        else:
            h = attn.attention_forward(p_l["attn"], cfg, h,
                                       positions=positions,
                                       window=cfg.local_attn_window)
        x = x + h
        h = layers.rmsnorm(x, p_l["ffn_norm"], cfg.norm_eps)
        x = x + mlp_mod.mlp_forward(p_l["mlp"], cfg, h)
        return constrain(x, ("batch", "seq", "act_embed"))

    # -- loss -------------------------------------------------------------------
    def loss(self, params, batch: Dict):
        logits, aux = self.forward(params, batch)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = jnp.ones_like(ll).at[:, -1].set(0.0)
        ce = -jnp.sum(ll * mask) / jnp.sum(mask)
        return ce + aux

    # =========================================================================
    # Serving: prefill + decode
    # =========================================================================

    def kv_quant_effective(self, kv_quant: Optional[str] = None) -> str:
        """Cache precision actually served. Recurrent families (SSM /
        RG-LRU hybrid) keep bf16 state regardless of ``cfg.kv_quant``:
        their per-layer state is small (no growth with context) and the
        sequential scan compounds rounding — ``kv_quant`` is a
        contract no-op there, verified by test."""
        kvq = self.cfg.kv_quant if kv_quant is None else kv_quant
        if self.cfg.arch_type in ("ssm", "hybrid"):
            return "bf16"
        return kvq

    def paging_effective(self, max_len: int, page_size: int) -> int:
        """Page size actually served, or 0 when the cache stays dense.

        Paging virtualizes *growing* full-attention caches; recurrent
        state (SSM / RG-LRU) is O(1) per slot and sliding-window rings
        are already capped, so a paged engine on those families is
        structurally dense — a contract no-op like ``kv_quant`` on
        recurrent archs."""
        if not page_size:
            return 0
        if self.cfg.arch_type not in ("dense", "moe", "vlm", "audio"):
            return 0
        if self.window_for(max_len):
            return 0
        return page_size

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   kv_quant: Optional[str] = None, page_size: int = 0,
                   cache_blocks: int = 0):
        cfg = self.cfg
        window = self.window_for(max_len)
        kvq = self.kv_quant_effective(kv_quant)
        page_size = self.paging_effective(max_len, page_size)
        if cfg.arch_type == "ssm":
            one = lambda: ssm_mod.init_ssm_cache(cfg, batch, dtype)
            cache = {"layers": _stack_pytrees(
                [one() for _ in range(cfg.num_layers)])}
        elif cfg.arch_type == "hybrid":
            per_layer = []
            for kind in cfg.layer_pattern():
                if kind == "rglru":
                    per_layer.append(hy.init_rglru_cache(cfg, batch, dtype))
                else:
                    per_layer.append(attn.init_kv_cache(
                        cfg, batch, max_len, cfg.local_attn_window, dtype))
            cache = {"layers": per_layer}
        else:
            one = lambda: attn.init_kv_cache(cfg, batch, max_len, window,
                                             dtype, kv_quant=kvq,
                                             page_size=page_size,
                                             num_blocks=cache_blocks)
            cache = {"layers": _stack_pytrees(
                [one() for _ in range(cfg.num_layers)])}
            if cfg.arch_type == "audio":
                L = cfg.num_layers
                S_enc = cfg.encoder_seq_len
                cache["cross_k"] = jnp.zeros(
                    (L, batch, cfg.num_kv_heads, S_enc, cfg.head_dim),
                    dtype)
                cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
                cache["cross_lens"] = jnp.zeros((batch,), jnp.int32)
        return cache

    def cache_axes(self, kv_quant: Optional[str] = None,
                   page_size: int = 0):
        """Logical axis names per cache leaf. Pass the *effective*
        ``page_size`` (see ``paging_effective``) to describe a paged
        cache — pool leaves then carry "kv_block"/"kv_page" instead of
        "batch"/"kv_seq", which is what keeps the batch-keyed
        splice/merge/freeze machinery off them."""
        cfg = self.cfg
        kvq = self.kv_quant_effective(kv_quant)
        if cfg.arch_type == "ssm":
            per = ssm_mod.ssm_cache_axes()
            return {"layers": jax.tree_util.tree_map(
                lambda a: (None,) + a, per,
                is_leaf=lambda x: isinstance(x, tuple))}
        if cfg.arch_type == "hybrid":
            out = []
            for kind in cfg.layer_pattern():
                out.append(hy.rglru_cache_axes() if kind == "rglru"
                           else attn.kv_cache_axes())
            return {"layers": out}
        axes = {"layers": jax.tree_util.tree_map(
            lambda a: (None,) + a,
            attn.kv_cache_axes(kvq, paged=bool(page_size)),
            is_leaf=lambda x: isinstance(x, tuple))}
        if cfg.arch_type == "audio":
            axes["cross_k"] = (None, "batch", None, "kv_seq", None)
            axes["cross_v"] = (None, "batch", None, "kv_seq", None)
            axes["cross_lens"] = ("batch",)
        return axes

    # -- prefill -----------------------------------------------------------------
    def prefill(self, params, batch: Dict, cache):
        """Run the prompt, fill the cache, return last-token logits.

        Optional ``batch["seq_lens"]`` (B,) marks per-sequence true
        lengths: tokens beyond ``seq_lens[i]`` are right-padding, so the
        engine can prefill several length-bucketed prompts in ONE
        dispatch. Padding rows write junk K/V past ``lens`` — harmless,
        because decode reads only ``kv_len = lens+1`` rows and
        overwrites the junk in order before it ever becomes visible.
        Recurrent archs (ssm/hybrid) carry state through every position,
        so callers must not pad them (the engine buckets those by exact
        length, making ``seq_lens`` uniform).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        seq_lens = batch.get("seq_lens")
        B, S = tokens.shape
        x = layers.embed(params, tokens)
        prefix_len = 0
        if cfg.arch_type == "vlm":
            x, prefix_len = _prepend_prefix(batch["prefix"], x)
        x = constrain(x, ("batch", "seq", "act_embed"))
        total = S + prefix_len
        positions = jnp.broadcast_to(jnp.arange(total), (B, total))
        window = self.window_for(total)

        new_cache = dict(cache)
        # effective per-sequence cache length incl. any VLM prefix
        eff_lens = None if seq_lens is None else seq_lens + prefix_len
        enc_out = None
        if cfg.arch_type == "audio":
            enc_out = self._encode(params, batch["frames"])

        if cfg.arch_type == "ssm":
            def body(carry, xs):
                h = carry
                p_l, c_l = xs
                z = layers.rmsnorm(h, p_l["norm"], cfg.norm_eps)
                z, (conv, state) = ssm_mod.ssm_forward(
                    p_l["ssm"], cfg, z, return_state=True)
                h = h + z
                c_new = dict(c_l, conv=conv,
                             state=state.astype(c_l["state"].dtype),
                             lens=c_l["lens"] + total)
                return h, c_new
            x, stacked = _layer_scan(body, x,
                                     (params["layers"], cache["layers"]),
                                     cfg.unroll_scans)
            new_cache["layers"] = stacked
        elif cfg.arch_type == "hybrid":
            new_layers = []
            for p_l, c_l, kind in zip(params["layers"], cache["layers"],
                                      cfg.layer_pattern()):
                x, c_new = self._hybrid_prefill_block(p_l, kind, x,
                                                      positions, c_l)
                new_layers.append(c_new)
            new_cache["layers"] = new_layers
        else:
            if cfg.arch_type == "audio":
                # precompute cross-attn KV once per layer
                def cross_body(_, p_l):
                    k, v = self._cross_kv(p_l["cross"], enc_out)
                    return None, (k, v)
                _, (ck, cv) = _layer_scan(cross_body, None,
                                          params["layers"],
                                          cfg.unroll_scans)
                new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
                new_cache["cross_lens"] = jnp.full(
                    (B,), enc_out.shape[1], jnp.int32)

            def body(carry, xs):
                h = carry
                p_l, c_l = xs
                z = layers.rmsnorm(h, p_l["attn_norm"], cfg.norm_eps)
                z, k, v = attn.attention_forward(
                    p_l["attn"], cfg, z, positions=positions,
                    window=window, return_kv=True)
                h = h + z
                c_new = _write_prefill_kv(c_l, k, v, total,
                                          seq_lens=eff_lens,
                                          kv_quant=cfg.kv_quant,
                                          group=cfg.quant_group)
                if "cross" in p_l:
                    z = layers.rmsnorm(h, p_l["cross_norm"], cfg.norm_eps)
                    kc, vc = self._cross_kv(p_l["cross"], enc_out)
                    z = attn.attention_forward(
                        p_l["cross"], cfg, z, positions=positions,
                        kv_override=(kc, vc), use_rope=False)
                    h = h + z
                z = layers.rmsnorm(h, p_l["ffn_norm"], cfg.norm_eps)
                if cfg.is_moe:
                    z, _ = moe_mod.moe_forward(p_l["moe"], cfg, z)
                else:
                    z = mlp_mod.mlp_forward(p_l["mlp"], cfg, z)
                h = h + z
                h = constrain(h, ("batch", "seq", "act_embed"))
                return h, c_new

            x, stacked = _layer_scan(body, x,
                                     (params["layers"], cache["layers"]),
                                     cfg.unroll_scans)
            new_cache["layers"] = stacked

        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if eff_lens is None:
            last = x[:, -1:]
        else:
            # per-sequence last real position (right-padded batch)
            last = jnp.take_along_axis(
                x, (eff_lens - 1)[:, None, None], axis=1)
        logits = layers.unembed(params, last, cfg)[:, 0]
        return logits[:, :cfg.vocab_size], new_cache

    def _hybrid_prefill_block(self, p_l, kind, x, positions, c_l):
        cfg = self.cfg
        h = layers.rmsnorm(x, p_l["attn_norm"], cfg.norm_eps)
        if kind == "rglru":
            h, c_new = hy.rglru_forward(p_l["rglru"], cfg, h, cache=None,
                                        return_state=True)
            c_new["conv"] = c_new["conv"].astype(c_l["conv"].dtype)
        else:
            h, k, v = attn.attention_forward(
                p_l["attn"], cfg, h, positions=positions,
                window=cfg.local_attn_window, return_kv=True)
            c_new = _write_prefill_kv(c_l, k, v, x.shape[1])
        x = x + h
        h = layers.rmsnorm(x, p_l["ffn_norm"], cfg.norm_eps)
        x = x + mlp_mod.mlp_forward(p_l["mlp"], cfg, h)
        return constrain(x, ("batch", "seq", "act_embed")), c_new

    # -- decode ------------------------------------------------------------------
    def decode_step(self, params, tokens, cache, advance_mask=None):
        """tokens: (B, 1) → (logits (B, vocab), new_cache).

        ``advance_mask`` (B,) bool — rows where it is False keep their
        cache frozen (no K/V write, no ``lens`` advance, no state
        update). The serving megastep uses this so retired (EOS /
        length-capped) slots can keep riding the fixed-shape batch
        through a ``lax.scan`` without corrupting their cache — and,
        since every cache family writes at its own per-row ``lens``
        cursor, the same machinery carries the engine's *chunked
        prefill admission*: a prefilling slot feeds prompt tokens
        through this step one per scan substep (its logits discarded
        until the last prompt position) while its decoding neighbours
        advance normally. For attention caches this is bit-identical
        to ``prefill`` on this container's backend; recurrent archs
        differ only by sequential-vs-associative scan rounding.
        """
        cfg = self.cfg
        B = tokens.shape[0]
        x = layers.embed(params, tokens)
        x = constrain(x, ("batch", None, "act_embed"))

        new_cache = dict(cache)
        if cfg.arch_type == "ssm":
            def body(carry, xs):
                h = carry
                p_l, c_l = xs
                z = layers.rmsnorm(h, p_l["norm"], cfg.norm_eps)
                z, c_new = ssm_mod.ssm_decode(p_l["ssm"], cfg, z, c_l)
                return h + z, _freeze_rows(c_new, c_l, advance_mask)
            x, stacked = _layer_scan(body, x,
                                     (params["layers"], cache["layers"]),
                                     cfg.unroll_scans)
            new_cache["layers"] = stacked
        elif cfg.arch_type == "hybrid":
            new_layers = []
            for p_l, c_l, kind in zip(params["layers"], cache["layers"],
                                      cfg.layer_pattern()):
                h = layers.rmsnorm(x, p_l["attn_norm"], cfg.norm_eps)
                if kind == "rglru":
                    h, c_new = hy.rglru_decode(p_l["rglru"], cfg, h, c_l)
                else:
                    h, c_new = attn.attention_decode(p_l["attn"], cfg, h,
                                                     c_l)
                x = x + h
                h = layers.rmsnorm(x, p_l["ffn_norm"], cfg.norm_eps)
                x = x + mlp_mod.mlp_forward(p_l["mlp"], cfg, h)
                new_layers.append(_freeze_rows(c_new, c_l, advance_mask))
            new_cache["layers"] = new_layers
        else:
            cross = cfg.arch_type == "audio"

            def body(carry, xs):
                h = carry
                if cross:
                    p_l, c_l, ck, cv = xs
                else:
                    p_l, c_l = xs
                z = layers.rmsnorm(h, p_l["attn_norm"], cfg.norm_eps)
                z, c_new = attn.attention_decode(p_l["attn"], cfg, z, c_l,
                                                 write_mask=advance_mask)
                c_new = _freeze_rows(c_new, c_l, advance_mask)
                h = h + z
                if cross:
                    z = layers.rmsnorm(h, p_l["cross_norm"], cfg.norm_eps)
                    zc, _ = attn.attention_decode(
                        p_l["cross"], cfg, z,
                        dict(c_l, cross_lens=cache["cross_lens"]),
                        kv_override=(ck, cv))
                    h = h + zc
                z = layers.rmsnorm(h, p_l["ffn_norm"], cfg.norm_eps)
                if cfg.is_moe:
                    z, _ = moe_mod.moe_forward(p_l["moe"], cfg, z)
                else:
                    z = mlp_mod.mlp_forward(p_l["mlp"], cfg, z)
                h = h + z
                return h, c_new

            xs = ((params["layers"], cache["layers"], cache["cross_k"],
                   cache["cross_v"]) if cross
                  else (params["layers"], cache["layers"]))
            x, stacked = _layer_scan(body, x, xs, cfg.unroll_scans)
            new_cache["layers"] = stacked

        x = layers.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = layers.unembed(params, x, cfg)[:, 0]
        return logits[:, :cfg.vocab_size], new_cache

    # -- single-request reference loop (serving oracle) --------------------------
    def reference_decode(self, params, prompt, max_new_tokens: int,
                         eos_id: int = -1, *, max_len: int = 64,
                         stepwise_prefill: bool = True):
        """Greedy single-request decode loop — the oracle the serving
        property suite holds the continuous-batching engine to.

        ``stepwise_prefill=True`` feeds the prompt one token at a time
        through ``decode_step`` (exactly the engine's chunked-admission
        path, and shape-stable: one compiled (1, 1) step serves every
        prompt length); ``False`` uses the fused ``prefill`` (the
        stall-admission path). Returns the generated token list
        (first sampled token included, stops at EOS / max_new).
        """
        if max_new_tokens <= 0:
            return []                # zero budget: nothing to generate
        if not hasattr(self, "_ref_jits"):
            self._ref_jits = (jax.jit(self.prefill),
                              jax.jit(self.decode_step))
        pre, dec = self._ref_jits
        cache = self.init_cache(1, max_len)
        prompt = jnp.asarray(prompt, jnp.int32)
        if stepwise_prefill:
            for t in prompt:
                logits, cache = dec(params, t[None, None], cache)
        else:
            logits, cache = pre(params, {"tokens": prompt[None]}, cache)
        out = [int(jnp.argmax(logits[0]))]
        while len(out) < max_new_tokens and out[-1] != eos_id:
            logits, cache = dec(
                params, jnp.asarray([[out[-1]]], jnp.int32), cache)
            out.append(int(jnp.argmax(logits[0])))
        return out


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_pytrees(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _freeze_rows(c_new, c_old, mask):
    """Length-frozen cache write mask: batch rows where ``mask`` is
    False keep ``c_old``. Every per-layer cache leaf (k/v/lens, SSM
    conv/state, RG-LRU conv/state) carries batch on axis 0, so one
    broadcast select covers all families.

    Paged caches are the exception: pool leaves carry the block id on
    axis 0, not batch, so a row select cannot undo a frozen row's
    write. Those writes were instead redirected to the garbage block
    inside ``attention_decode`` (via ``write_mask``); here only the
    per-slot leaves (``lens``, ``block_table``) get the batch select."""
    if mask is None:
        return c_new
    def sel(n, o):
        m = mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    if isinstance(c_new, dict) and "block_table" in c_new:
        out = dict(c_new)
        out["lens"] = sel(c_new["lens"], c_old["lens"])
        out["block_table"] = sel(c_new["block_table"],
                                 c_old["block_table"])
        return out
    return jax.tree_util.tree_map(sel, c_new, c_old)


def _prepend_prefix(prefix, x):
    """Prepend VLM patch embeddings via dynamic_update_slice into a
    padded buffer. A plain concatenate of two differently-sized pieces
    along a sharded sequence axis sends the SPMD partitioner into an
    'involuntary full rematerialization' corner (replicates the whole
    activations); DUS into one buffer partitions cleanly."""
    B, S, D = x.shape
    P_len = prefix.shape[1]
    buf = jnp.zeros((B, P_len + S, D), x.dtype)
    buf = jax.lax.dynamic_update_slice(buf, prefix.astype(x.dtype),
                                       (0, 0, 0))
    buf = jax.lax.dynamic_update_slice(buf, x, (0, P_len, 0))
    return buf, P_len


def _layer_scan(body, carry, xs, unroll: bool):
    """lax.scan over stacked layer params/caches, or a python loop when
    ``unroll`` (cost-calibration mode — while bodies are counted once by
    XLA cost_analysis, so the dry-run unrolls to get true totals)."""
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked


def _write_prefill_kv(c_l, k, v, total_len: int, seq_lens=None,
                      kv_quant: str = "bf16", group: int = 32):
    """Write prefill K/V (B, Hkv, S, hd) into the cache (ring-aware).

    ``seq_lens`` (B,) — per-sequence true lengths for right-padded
    batches; only valid on the non-ring path (padded prompts never
    exceed the cache window; the engine guarantees this).

    Quantized caches (``k_scale`` leaf present) quantize the whole
    prefill block at the write point — per-position groupwise along
    head_dim, so the rows written here are bit-identical to what the
    stepwise ``decode_step`` path would have written one at a time
    (each position's scale depends only on its own values).
    """
    from repro.quant.quantize import quantize_rows
    if "k_scale" in c_l:
        kq, ks = quantize_rows(k, kv_quant, group)
        vq, vs = quantize_rows(v, kv_quant, group)
        updates = {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    else:
        updates = {"k": k, "v": v}
    S_cache = c_l["k"].shape[2]
    S = k.shape[2]
    new = {}
    for name, arr in updates.items():
        tgt = c_l[name]
        arr = arr.astype(tgt.dtype)
        if S <= S_cache:
            new[name] = jax.lax.dynamic_update_slice(
                tgt, arr, (0,) * tgt.ndim)
        else:
            # keep the last S_cache entries, at slot = pos % S_cache
            new[name] = jnp.roll(arr[:, :, -S_cache:],
                                 total_len % S_cache, axis=2)
    if S > S_cache:
        seq_lens = None        # ring path is uniform-length by contract
    adv = total_len if seq_lens is None else seq_lens
    return dict(c_l, **new, lens=c_l["lens"] + adv)


# ---------------------------------------------------------------------------
# Dry-run input specs (deliverable e/f)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, seq_len: int, global_batch: int,
                kind: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input.

    train → {tokens, labels}; prefill → {tokens, (frames|prefix)};
    decode → {tokens (B,1)} (cache comes from ``Model.init_cache``
    abstractified separately). Audio/VLM frontends are stubs: the
    specs provide the precomputed embeddings directly (the one
    carve-out to "no stubs").
    """
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len), i32),
               "labels": jax.ShapeDtypeStruct((global_batch, seq_len), i32)}
    elif kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len), i32)}
    elif kind == "decode":
        out = {"tokens": jax.ShapeDtypeStruct((global_batch, 1), i32)}
    else:
        raise ValueError(kind)
    if cfg.arch_type == "audio" and kind in ("train", "prefill"):
        out["frames"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.encoder_seq_len, cfg.d_model), bf16)
    if cfg.arch_type == "vlm" and kind in ("train", "prefill"):
        out["prefix"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.num_prefix_embeddings, cfg.d_model), bf16)
    return out
