"""FFN block — the paper's dominant GEMM cost center (Fig 6).

``fuse_gate_up`` concatenates the two independent SwiGLU projections
into one GEMM (paper V1 graph-parallelism on TPU). Column/row Megatron
sharding comes from the logical axes: gate/up are column-parallel on
``mlp``, down is row-parallel back to ``embed``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec


def mlp_specs(cfg: ModelConfig) -> Dict:
    D, F = cfg.d_model, cfg.d_ff
    specs: Dict = {}
    if cfg.glu:
        if cfg.fuse_gate_up:
            specs["w_gate_up"] = layers.linear_spec(D, 2 * F,
                                                    ("embed", "mlp"))
        else:
            specs["w_gate"] = layers.linear_spec(D, F, ("embed", "mlp"))
            specs["w_up"] = layers.linear_spec(D, F, ("embed", "mlp"))
    else:
        specs["w_up"] = layers.linear_spec(D, F, ("embed", "mlp"))
    specs["w_down"] = layers.linear_spec(F, D, ("mlp", "embed"))
    return specs


def mlp_forward(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = layers.activation_fn(cfg.activation)
    up_kw = dict(use_pallas=cfg.use_pallas)
    if cfg.glu:
        if "w_gate_up" in p:
            gu = layers.linear(p["w_gate_up"], x, **up_kw)
            gu = constrain(gu, ("batch", None, "mlp"))
            g, u = jnp.split(gu, 2, axis=-1)
        else:
            g = layers.linear(p["w_gate"], x, **up_kw)
            u = layers.linear(p["w_up"], x, **up_kw)
        h = act(g) * u
    else:
        h = act(layers.linear(p["w_up"], x, **up_kw))
    h = constrain(h, ("batch", None, "mlp"))
    return layers.linear(p["w_down"], h, **up_kw)
