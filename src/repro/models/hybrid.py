"""RecurrentGemma blocks: RG-LRU recurrence + local (sliding-window)
attention, interleaved 1:2 [arXiv:2402.19427].

The RG-LRU input/gate projections are independent GEMMs on the same
input → fused (paper's technique, DESIGN.md §4). The recurrence itself
is a gated linear scan, computed with ``jax.lax.associative_scan``
(log-depth, TPU-friendly) for prefill/training and an O(1) update for
decode — which is what makes this arch ``long_500k``-native.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers
from repro.models.params import ParamSpec

_C = 8.0   # RG-LRU decay sharpness constant (paper value)


def rglru_specs(cfg: ModelConfig) -> Dict:
    D = cfg.d_model
    w = cfg.rglru_width or D
    return {
        # fused: x-branch projection and gate projection share the input
        "in_proj": {"w": ParamSpec((D, 2 * w), ("embed", "qkv_fused"))},
        "conv_w": ParamSpec((4, w), ("conv", None)),
        "conv_b": ParamSpec((w,), (None,), init="zeros"),
        # per-channel recurrence/input gates
        "wa": {"w": ParamSpec((w, w), ("heads", None)),
               "b": ParamSpec((w,), (None,), init="zeros")},
        "wx": {"w": ParamSpec((w, w), ("heads", None)),
               "b": ParamSpec((w,), (None,), init="zeros")},
        "a_param": ParamSpec((w,), (None,), init="small_a"),
        "out_proj": {"w": ParamSpec((w, D), ("heads", "embed"))},
    }


def _gates(p, x: jax.Array):
    """Recurrence gate a_t and input gate i_t (both (B,S,w))."""
    r = jax.nn.sigmoid(layers.linear(p["wa"], x))
    i = jax.nn.sigmoid(layers.linear(p["wx"], x))
    log_a = -_C * jax.nn.softplus(p["a_param"].astype(jnp.float32))
    a = jnp.exp(log_a[None, None] * r.astype(jnp.float32))   # (B,S,w)
    return a, i


def rglru_scan(x_gated: jax.Array, a: jax.Array,
               init_state: Optional[jax.Array] = None) -> jax.Array:
    """h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t via associative scan."""
    xf = x_gated.astype(jnp.float32)
    af = a.astype(jnp.float32)
    b = jnp.sqrt(jnp.clip(1.0 - af ** 2, 1e-12)) * xf
    if init_state is not None:
        # fold the carried state into the first element
        b = b.at[:, 0].add(af[:, 0] * init_state.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (af, b), axis=1)
    return h


def rglru_forward(p, cfg: ModelConfig, x: jax.Array,
                  cache: Optional[Dict] = None, return_state: bool = False):
    """RG-LRU temporal block. x: (B, S, D)."""
    B, S, D = x.shape
    w = cfg.rglru_width or D
    xg = layers.linear(p["in_proj"], x, use_pallas=cfg.use_pallas)
    xg = constrain(xg, ("batch", None, "qkv_fused"))
    xb, gate = jnp.split(xg, 2, axis=-1)
    conv_state = cache.get("conv") if cache else None
    from repro.models.ssm import _causal_conv
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    a, i = _gates(p, xb)
    h = rglru_scan(xb.astype(jnp.float32) * i.astype(jnp.float32), a,
                   init_state=cache.get("state") if cache else None)
    h = (h * layers.gelu(gate.astype(jnp.float32))).astype(x.dtype)
    out = layers.linear(p["out_proj"], h, use_pallas=cfg.use_pallas)
    if return_state:
        new_cache = {"conv": new_conv, "state": h[:, -1].astype(jnp.float32),
                     "lens": (cache["lens"] + S if cache else
                              jnp.full((B,), S, jnp.int32))}
        return out, new_cache
    return out


def rglru_decode(p, cfg: ModelConfig, x: jax.Array, cache: Dict
                 ) -> Tuple[jax.Array, Dict]:
    """O(1) single-token update. x: (B, 1, D)."""
    B = x.shape[0]
    xg = layers.linear(p["in_proj"], x, use_pallas=cfg.use_pallas)
    xb, gate = jnp.split(xg, 2, axis=-1)
    from repro.models.ssm import _causal_conv
    xb, new_conv = _causal_conv(xb, p["conv_w"], p["conv_b"], cache["conv"])
    a, i = _gates(p, xb)                                # (B,1,w)
    af = a[:, 0].astype(jnp.float32)
    xf = (xb[:, 0] * i[:, 0]).astype(jnp.float32)
    h_prev = cache["state"].astype(jnp.float32)         # (B, w)
    h = af * h_prev + jnp.sqrt(jnp.clip(1 - af ** 2, 1e-12)) * xf
    y = (h * layers.gelu(gate[:, 0].astype(jnp.float32)))[:, None]
    out = layers.linear(p["out_proj"], y.astype(x.dtype),
                        use_pallas=cfg.use_pallas)
    new_cache = dict(cache, conv=new_conv, state=h,
                     lens=cache["lens"] + 1)
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    w = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),
        "state": jnp.zeros((batch, w), jnp.float32),
        "lens": jnp.zeros((batch,), jnp.int32),
    }


def rglru_cache_axes() -> Dict:
    return {"conv": ("batch", None, "qkv_fused"),
            "state": ("batch", "heads"),
            "lens": ("batch",)}
