"""Mixture-of-Experts FFN with expert-parallel dispatch.

The paper's insight — independent GEMMs should be dispatched
concurrently across compute resources — is *structurally* what expert
parallelism is: the E experts are independent GEMM stacks, sharded over
the ``model``/``expert`` mesh axis, with the token all-to-all as the
dispatch. (DESIGN.md §4, kimi-k2 / phi3.5-moe rows.)

Dispatch is sort-based with a static capacity (no (T, E) one-hot — that
would be a 1.5 TB tensor for kimi-k2 at train_4k):

  1. router top-k per token,
  2. argsort token-expert pairs by expert id,
  3. scatter into an (E, C, D) buffer (tokens over capacity drop —
     ``capacity_factor`` bounds the loss),
  4. per-expert GEMMs via batched einsum, E sharded on ``model``,
  5. gather back, weight by router probs, sum over k.

GSPMD turns the resharding at steps 3/5 into the all-to-all that the
roofline's collective term tracks.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers, mlp
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig) -> Dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    gu_cols = 2 * F if cfg.glu else F
    specs: Dict = {
        "router": {"w": ParamSpec((D, E), ("embed", "expert"))},
        "experts": {
            "w_gate_up": ParamSpec((E, D, gu_cols),
                                   ("expert", "embed", None)),
            "w_down": ParamSpec((E, F, D), ("expert", None, "embed")),
        },
    }
    if cfg.num_shared_experts:
        shared_cfg = cfg  # same dims as one expert
        specs["shared"] = {
            "w_gate_up": ParamSpec(
                (D, cfg.num_shared_experts * gu_cols), ("embed", "mlp")),
            "w_down": ParamSpec(
                (cfg.num_shared_experts * F, D), ("mlp", "embed")),
        }
    return specs


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.experts_per_token *
                      cfg.capacity_factor / cfg.num_experts))
    return max(8, ((c + 7) // 8) * 8)   # pad to a multiple of 8


def moe_forward(p, cfg: ModelConfig, x: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) → (out, aux_loss)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = capacity(cfg, T)
    act = layers.activation_fn(cfg.activation)

    xf = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)             # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)     # (T, K)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # -- load-balance auxiliary loss (Switch-style) ---------------------
    me = jnp.mean(probs, axis=0)                                 # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # -- sort-based dispatch --------------------------------------------
    flat_expert = expert_idx.reshape(-1)                         # (T*K,)
    order = jnp.argsort(flat_expert)                             # stable
    sorted_expert = flat_expert[order]
    token_of = order // K
    # slot within expert = rank among same-expert entries
    ar = jnp.arange(T * K)
    first_of_expert = jnp.searchsorted(sorted_expert, sorted_expert,
                                       side="left")
    slot = ar - first_of_expert                                  # (T*K,)
    keep = slot < C

    buf = jnp.zeros((E, C, D), x.dtype)
    buf = buf.at[jnp.where(keep, sorted_expert, E - 1),
                 jnp.where(keep, slot, C - 1)].add(
        jnp.where(keep[:, None], xf[token_of], 0).astype(x.dtype),
        mode="drop")
    buf = constrain(buf, ("expert", "expert_cap", None))

    # -- expert GEMMs (batched over E, sharded on model) ----------------
    gu = jnp.einsum("ecd,edf->ecf", buf, p["experts"]["w_gate_up"],
                    preferred_element_type=jnp.float32)
    if cfg.glu:
        g, u = jnp.split(gu, 2, axis=-1)
        h = act(g) * u
    else:
        h = act(gu)
    y_buf = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype),
                       p["experts"]["w_down"],
                       preferred_element_type=jnp.float32)
    y_buf = constrain(y_buf.astype(x.dtype), ("expert", "expert_cap", None))

    # -- gather back + combine ------------------------------------------
    gathered = jnp.where(
        keep[:, None], y_buf[sorted_expert, jnp.minimum(slot, C - 1)], 0)
    inv = jnp.zeros_like(order).at[order].set(ar)
    per_pair = gathered[inv].reshape(T, K, D)
    out = jnp.einsum("tkd,tk->td", per_pair.astype(jnp.float32),
                     gate_vals).astype(x.dtype)

    # -- shared experts (always-on, Kimi-K2 style) -----------------------
    if "shared" in p:
        gu_s = jnp.einsum("td,df->tf", xf, p["shared"]["w_gate_up"],
                          preferred_element_type=jnp.float32)
        if cfg.glu:
            g, u = jnp.split(gu_s, 2, axis=-1)
            h_s = act(g) * u
        else:
            h_s = act(gu_s)
        out = out + jnp.einsum(
            "tf,fd->td", h_s.astype(x.dtype), p["shared"]["w_down"],
            preferred_element_type=jnp.float32).astype(x.dtype)

    return out.reshape(B, S, D), aux
