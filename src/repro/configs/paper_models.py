"""The paper's own evaluated models (Table/Fig 4 of the paper).

These are the six models the study benchmarks on the iPhone 15 Pro.
They are used by the paper-faithful reproduction benchmarks
(``benchmarks/fig4_throughput.py`` etc.) and as small end-to-end demo
models; llama3.2-1b is the paper's primary profiling target (§6).
"""
from repro.configs.base import ModelConfig

LLAMA32_1B = ModelConfig(
    name="llama3.2-1b",
    arch_type="dense",
    source="[arXiv:2407.21783]",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
    max_seq_len=131072,
)

LLAMA32_3B = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    source="[arXiv:2407.21783]",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
    max_seq_len=131072,
)

LLAMA31_8B = ModelConfig(
    name="llama3.2-8b",  # paper's label; arch == llama-3.1-8B
    arch_type="dense",
    source="[arXiv:2407.21783]",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    max_seq_len=131072,
)

QWEN2_0_5B = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    source="[arXiv:2407.10671]",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    max_seq_len=32768,
)

QWEN2_1_5B = ModelConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    source="[arXiv:2407.10671]",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    max_seq_len=32768,
)

MISTRAL_7B = ModelConfig(
    name="mistral-7b-v0.1",
    arch_type="dense",
    source="[arXiv:2310.06825]",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    max_seq_len=32768,
)

PAPER_MODELS = {
    m.name: m
    for m in (QWEN2_0_5B, QWEN2_1_5B, LLAMA32_1B, LLAMA32_3B, MISTRAL_7B,
              LLAMA31_8B)
}
