"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE [hf:microsoft/Phi-3.5-MoE-instruct].

32 layers, d_model=4096, 32 heads (GQA kv=8), per-expert d_ff=6400,
vocab=32064, MoE 16 experts top-2.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    source="[hf:microsoft/Phi-3.5-MoE-instruct]",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    num_experts=16,
    experts_per_token=2,
    capacity_factor=1.25,
    max_seq_len=131072,
)
