"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    reduced,
)

# arch-id → module (one file per assigned architecture)
_ASSIGNED = {
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe_42b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ASSIGNED_ARCHS = tuple(_ASSIGNED)


def get_config(name: str, **overrides) -> ModelConfig:
    """Look up an architecture config by id (assigned or paper model)."""
    if name in _ASSIGNED:
        cfg = importlib.import_module(_ASSIGNED[name]).CONFIG
    else:
        from repro.configs.paper_models import PAPER_MODELS
        if name not in PAPER_MODELS:
            raise KeyError(
                f"unknown arch {name!r}; known: {sorted(_ASSIGNED) + sorted(PAPER_MODELS)}")
        cfg = PAPER_MODELS[name]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in _ASSIGNED}


__all__ = [
    "ModelConfig", "InputShape", "INPUT_SHAPES", "reduced",
    "get_config", "all_configs", "ASSIGNED_ARCHS",
]
