"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

Attention-free SSM: 64 layers, d_model=2560, ssm_state=128, vocab=50280.
d_inner = 2*2560 = 5120, 80 SSD heads of dim 64. The paper's QKV-fusion
technique maps to fusing the SSD in_proj (z,x,B,C,dt share the input).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    source="[arXiv:2405.21060]",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    max_seq_len=1 << 20,
)
