"""mistral-nemo-12b — dense, 128k context [hf:mistralai/Mistral-Nemo-Base-2407].

40 layers, d_model=5120, 32 heads of dim 128 (GQA kv=8; q_dim 4096 !=
d_model, per the card), d_ff=14336, vocab=131072.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    source="[hf:mistralai/Mistral-Nemo-Base-2407]",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    max_seq_len=131072,
)
