"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1:2 [arXiv:2402.19427].

26 layers, d_model=2560, 10 heads of dim 256 (MQA kv=1), d_ff=7680,
vocab=256000. Block pattern (rglru, rglru, attn) repeated — two
recurrent blocks per local-attention block, window 2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    source="[arXiv:2402.19427]",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    activation="gelu",
    tie_embeddings=True,
    hybrid_pattern=("rglru", "rglru", "attn"),
    rglru_width=2560,
    local_attn_window=2048,
    max_seq_len=1 << 20,
)
