"""seamless-m4t-medium — audio encoder-decoder [arXiv:2308.11596].

Transformer backbone only: 12 encoder + 12 decoder layers, d_model=1024,
16 heads (MHA kv=16), d_ff=4096, vocab=256206. The mel-spectrogram +
conv feature extractor frontend is a STUB — ``input_specs`` provides
precomputed frame embeddings (batch, frames, d_model) for the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    arch_type="audio",
    source="[arXiv:2308.11596]",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    activation="gelu",
    glu=False,
    is_encoder_decoder=True,
    num_encoder_layers=12,
    encoder_seq_len=4096,
    max_seq_len=8192,
)
