"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2].

61 layers, d_model=7168, 64 heads (GQA kv=8), per-expert d_ff=2048,
vocab=163840. MoE: 384 experts, top-8, plus 1 shared expert (K2 card).
head_dim=128 chosen for MXU alignment (the assigned spec pins
L/d_model/H/kv/d_ff/vocab only).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    source="[arXiv:2501.kimi2]",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
    capacity_factor=1.25,
    max_seq_len=131072,
)
