"""Central model/runtime configuration.

One ``ModelConfig`` dataclass covers all six assigned architecture
families (dense / moe / ssm / hybrid / audio / vlm). Family-specific
fields default to "off" so a dense config never sees MoE or SSM state.

The execution-strategy knobs (``fuse_qkv``, ``fuse_gate_up``,
``quant_policy``, ``scheduler_version``) are the paper's contribution
surfaced as first-class config: they select between the paper's V0
(serial, unfused), V1 (graph-level fusion), V2 (fusion + tensor
parallelism) and V3 (cross-axis split — the regression case).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Enumerations (plain strings to keep configs trivially serializable)
# ---------------------------------------------------------------------------

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")

# Paper precisions: F16 baseline, Q8_0 and Q4_0 k-quant analogues.
PRECISIONS = ("f32", "bf16", "f16", "q8_0", "q4_0")

# Paper §7 execution versions, adapted to TPU (see DESIGN.md §2).
#   v0: serial, no fusion          (paper baseline, 11.5 tk/s)
#   v1: graph-level fusion         (fused qkv / gate-up, 13 tk/s)
#   v2: v1 + tensor parallelism    (fused GEMMs sharded on `model`, 15 tk/s)
#   v3: cross-axis split           (attention/FFN on different axes, 6 tk/s)
SCHEDULER_VERSIONS = ("v0", "v1", "v2", "v3")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------
    name: str = "unnamed"
    arch_type: str = "dense"  # one of ARCH_TYPES
    source: str = ""          # citation, e.g. "[arXiv:2401.02954]"

    # --- transformer backbone ----------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4          # GQA; == num_heads → MHA, 1 → MQA
    head_dim: int = 0              # 0 → d_model // num_heads
    d_ff: int = 1024               # per-expert d_ff when MoE
    vocab_size: int = 1024
    max_seq_len: int = 131072
    qkv_bias: bool = False         # Qwen1.5 style
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    activation: str = "silu"       # "silu" (SwiGLU) | "gelu" (GeGLU/plain)
    glu: bool = True               # gated MLP (gate+up) vs plain up

    # --- attention variants -------------------------------------------
    sliding_window: int = 0        # 0 → full attention; >0 → window size
    # window applied only for long-context decode when `window_long_ctx`
    window_long_ctx: int = 4096    # window used when seq exceeds max_full_attn
    max_full_attn: int = 131072    # beyond this, dense archs switch to window

    # --- MoE -----------------------------------------------------------
    num_experts: int = 0           # 0 → dense FFN
    experts_per_token: int = 0     # top-k
    capacity_factor: float = 1.25
    num_shared_experts: int = 0    # always-on shared experts (Kimi K2 style)
    router_aux_weight: float = 0.01

    # --- SSM (Mamba-2 / SSD) -------------------------------------------
    ssm_state: int = 0             # N (state dim); 0 → no SSM
    ssm_expand: int = 2            # d_inner = expand * d_model
    ssm_head_dim: int = 64         # P
    ssm_chunk: int = 256           # SSD chunk length
    ssm_conv: int = 4              # short conv width

    # --- hybrid (RecurrentGemma) ----------------------------------------
    # block pattern, e.g. ("rglru", "rglru", "attn") repeated — 1:2 ratio
    hybrid_pattern: Tuple[str, ...] = ()
    rglru_width: int = 0           # lru width; 0 → d_model
    local_attn_window: int = 2048

    # --- encoder-decoder (audio) ----------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 4096    # stub frontend frames fed to encoder

    # --- multimodal stubs ------------------------------------------------
    num_prefix_embeddings: int = 0  # VLM patch embeddings prepended (stub)

    # --- execution strategy (the paper's technique) ----------------------
    scheduler_version: str = "v2"  # v0/v1/v2/v3 — see SCHEDULER_VERSIONS
    fuse_qkv: bool = True          # derived from scheduler_version unless forced
    fuse_gate_up: bool = True
    quant_policy: str = "bf16"     # weights precision: bf16|q8_0|q4_0
    quant_group: int = 32          # k-quant group size along reduction dim
    # KV-cache precision (the other half of the decode bandwidth story:
    # the cache stream grows with context/batch while weights don't).
    # Groupwise-quantized int8 payload + per-(position, head, group)
    # scales stored as sibling cache leaves. No-op for recurrent
    # families (ssm/hybrid): their state is small and
    # precision-sensitive, see Model.kv_quant_effective().
    kv_quant: str = "bf16"         # cache precision: bf16|q8_0|q4_0
    use_pallas: bool = False       # use Pallas kernels (interpret on CPU)
    # Kernel backend: one switch for the whole fused-dequant path
    # (quant_matmul decode GEMVs + the quantized-KV decode-attention
    # kernel). "" (default) derives from use_pallas for backwards
    # compatibility; an explicit "pallas"/"xla" wins and rewrites
    # use_pallas to match, so call sites keep reading cfg.use_pallas.
    kernels: str = ""              # ""|"xla"|"pallas"
    remat: bool = True             # activation checkpointing per layer
    # Cost-calibration mode (launch/dryrun.py): python-loop the layer
    # stack and unroll inner scans so XLA cost_analysis counts every
    # iteration (while-loop bodies are otherwise counted once).
    unroll_scans: bool = False
    attn_block: int = 512          # chunked-attention q/kv block size

    # --- numerics ---------------------------------------------------------
    dtype: str = "bf16"            # activation dtype
    param_dtype: str = "bf16"

    # -------------------------------------------------------------------
    def __post_init__(self):
        assert self.arch_type in ARCH_TYPES, self.arch_type
        assert self.scheduler_version in SCHEDULER_VERSIONS
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        # scheduler version drives fusion flags unless caller overrode them
        if self.scheduler_version == "v0":
            object.__setattr__(self, "fuse_qkv", False)
            object.__setattr__(self, "fuse_gate_up", False)
        # kernels is the one public switch; reconcile with the legacy
        # use_pallas bool (kernels wins when set, derives otherwise)
        if self.kernels == "":
            object.__setattr__(self, "kernels",
                               "pallas" if self.use_pallas else "xla")
        elif self.kernels in ("xla", "pallas"):
            object.__setattr__(self, "use_pallas", self.kernels == "pallas")
        else:
            raise ValueError(
                f"kernels must be '', 'xla' or 'pallas', got "
                f"{self.kernels!r}")

    # --- derived quantities ----------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the embedding/lm_head
        shard evenly on any mesh axis (standard practice; mamba's 50280
        and seamless's 256206 don't divide 16). Padded logits classes
        are trained down like any other unused token."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def layer_pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length == num_layers."""
        if self.arch_type == "ssm":
            return ("ssm",) * self.num_layers
        if self.arch_type == "hybrid":
            pat = self.hybrid_pattern or ("rglru", "rglru", "attn")
            return tuple(pat[i % len(pat)] for i in range(self.num_layers))
        return ("attn",) * self.num_layers

    # --- parameter counting (for roofline MODEL_FLOPS = 6·N·D) -----------
    def param_count(self, active_only: bool = False) -> int:
        """Total (or active, for MoE) parameter count, embeddings included."""
        D, H = self.d_model, self.head_dim
        n = self.vocab_size * D  # embeddings
        if not self.tie_embeddings:
            n += self.vocab_size * D  # lm head
        per_layer = 0
        for kind in self.layer_pattern():
            if kind == "attn":
                attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
                if self.qkv_bias:
                    attn += self.q_dim + 2 * self.kv_dim
                per_layer += attn + 2 * D  # + norms
                per_layer += self._ffn_params(active_only)
            elif kind == "rglru":
                w = self.rglru_width or D
                # input/gate proj + recurrent diag params + out proj
                per_layer += 2 * D * w + 4 * w + w * D + 2 * D
                per_layer += self._ffn_params(active_only)
            elif kind == "ssm":
                di, N, nh = self.d_inner, self.ssm_state, self.ssm_heads
                # in_proj → [z, x, B, C, dt]
                in_proj = D * (2 * di + 2 * N + nh)
                out_proj = di * D
                conv = self.ssm_conv * (di + 2 * N)
                per_layer += in_proj + out_proj + conv + nh * 2 + 2 * D
        n += per_layer
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ffn; decoder already counted above,
            # add cross-attention per decoder layer
            enc = self.num_encoder_layers * (
                D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
                + self._ffn_params(active_only) + 2 * D)
            cross = self.num_layers * (
                D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D + D)
            n += enc + cross
        return n

    def _ffn_params(self, active_only: bool) -> int:
        D, F = self.d_model, self.d_ff
        if F == 0:
            return 0
        dense_ffn = (3 if self.glu else 2) * D * F
        if not self.is_moe:
            return dense_ffn
        k = self.experts_per_token if active_only else self.num_experts
        shared = self.num_shared_experts * dense_ffn
        router = D * self.num_experts
        return k * dense_ffn + shared + router


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests."""
    base = dict(
        num_layers=2,
        d_model=min(cfg.d_model, 128),
        num_heads=min(cfg.num_heads, 4),
        num_kv_heads=min(cfg.num_kv_heads, 2),
        head_dim=32,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=512,
        remat=False,
    )
    if cfg.is_moe:
        base["num_experts"] = min(cfg.num_experts, 4)
        base["experts_per_token"] = min(cfg.experts_per_token, 2)
        base["num_shared_experts"] = min(cfg.num_shared_experts, 1)
    if cfg.arch_type == "ssm":
        base["d_model"] = 128
        base["ssm_state"] = min(cfg.ssm_state, 16)
        base["ssm_head_dim"] = 32
        base["ssm_chunk"] = 64
    if cfg.arch_type == "hybrid":
        base["rglru_width"] = 0
        base["local_attn_window"] = 64
        base["num_layers"] = 3  # one full rglru-rglru-attn pattern
    if cfg.is_encoder_decoder:
        base["num_encoder_layers"] = 2
        base["encoder_seq_len"] = 64
    if cfg.num_prefix_embeddings:
        base["num_prefix_embeddings"] = 16
    # GQA ratio sanity: kv must divide heads
    if base["num_heads"] % max(base["num_kv_heads"], 1):
        base["num_kv_heads"] = 1
    base.update(over)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
