"""paligemma-3b — VLM: SigLIP vision stub + Gemma decoder [arXiv:2407.07726].

Language backbone: 18 layers, d_model=2048, 8 heads (MQA kv=1, head_dim
256 per the Gemma card), d_ff=16384, vocab=257216. The SigLIP encoder +
projector is a STUB: ``input_specs`` supplies 256 precomputed patch
embeddings of shape (batch, 256, d_model) prepended to the text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    source="[arXiv:2407.07726]",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    activation="gelu",
    tie_embeddings=True,
    num_prefix_embeddings=256,
    max_seq_len=8192,
)
