"""Logical-axis sharding rules (GSPMD / pjit).

Models annotate every parameter and key activation with *logical* axis
names; the rules below map those to physical mesh axes. The four rule
sets encode the paper's §7 execution versions on a pod (DESIGN.md §2):

- ``v0``/``v1`` — no tensor parallelism (the paper's CPU-threads-only
  configurations). Weights are FSDP-sharded on ``data`` only so that
  compile-time memory still fits; the ``model`` axis carries sequence
  sharding only. v0 additionally disables GEMM fusion (a model-level
  flag, not a sharding concern).
- ``v2`` — fusion + tensor parallelism: Megatron column/row sharding on
  ``model``, FSDP on ``data``, batch on (``pod``, ``data``). The
  production default.
- ``v3`` — the paper's regression case: the attention block and the FFN
  block are deliberately sharded on *different* mesh axes, so GSPMD must
  reshard the residual stream at every block boundary. This reproduces,
  structurally, the CPU+GPU split that dropped throughput from 15 to
  6 tk/s (collective term explodes — see benchmarks/scheduler_versions).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping logical axis name → physical mesh axes (or None)."""
    name: str
    rules: Dict[str, MeshAxes]

    def get(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical, None)


# Logical axes used throughout the code base:
#   batch      — global batch
#   seq        — sequence (activations; sharded for sequence parallelism)
#   kv_seq     — KV-cache sequence dim
#   embed      — d_model / residual feature dim of *parameters* (FSDP dim)
#   mlp        — d_ff (column-parallel dim)
#   heads      — attention projection output features (q_dim / kv_dim)
#   qkv_fused  — fused QKV output features
#   vocab      — vocabulary dim
#   expert     — MoE expert dim
#   conv       — ssm conv kernel dim
#   state      — ssm state dim
#   act_embed  — d_model of *activations* (normally unsharded)

_COMMON = {
    "batch": ("pod", "data"),
    "act_embed": None,
    "conv": None,
    "state": None,
    "expert_cap": ("pod", "data"),   # MoE token-buffer capacity dim
}

RULES_V0 = AxisRules("v0", {
    **_COMMON,
    "seq": "model",      # seq-shard activations so full models fit
    "kv_seq": "model",
    "embed": "data",     # FSDP only — no tensor parallelism (paper v0/v1)
    "mlp": None,
    "heads": None,
    "qkv_fused": None,
    "vocab": None,
    "expert": "model",   # experts are data-independent; always shardable
})

RULES_V1 = AxisRules("v1", dict(RULES_V0.rules))

RULES_V2 = AxisRules("v2", {
    **_COMMON,
    "seq": "model",
    "kv_seq": "model",
    "embed": "data",     # FSDP
    "mlp": "model",      # Megatron column-parallel
    "heads": "model",
    "qkv_fused": "model",
    "vocab": "model",
    "expert": "model",
})

# v3: FFN tensor-sharded on *data*, attention on *model* — the
# cross-device split. Batch for FFN lands on model: every block boundary
# re-lays-out the residual stream.
RULES_V3 = AxisRules("v3", {
    **_COMMON,
    "seq": None,
    "kv_seq": "model",
    "embed": None,
    "mlp": "data",       # <-- conflicting axis: forces reshard per block
    "heads": "model",
    "qkv_fused": "model",
    "vocab": "model",
    "expert": "data",
    "expert_cap": "model",
})

# Beyond-paper ruleset (§Perf): full 2-D tensor parallelism for decode.
# v2's FSDP dimension ("embed" → data) forces an all-gather of every
# layer's weights each decode step — fine for training (amortized over
# 1M tokens), catastrophic for decode (128 tokens/step). tp2d shards
# every weight over BOTH mesh axes on its *output* features so each
# chip streams only params/256 bytes per step and the only collectives
# are small activation all-reduces after row-parallel projections.
RULES_TP2D = AxisRules("tp2d", {
    "batch": "data",          # KV cache batch dim
    "act_embed": None,
    "conv": None,
    "state": None,
    "expert_cap": None,
    "seq": None,
    "kv_seq": "model",
    "embed": None,            # no FSDP dim — weights fully TP-sharded
    "mlp": ("data", "model"),
    "heads": ("data", "model"),
    "qkv_fused": ("data", "model"),
    "vocab": ("data", "model"),
    "expert": ("data", "model"),
})

# Hillclimb iteration 2 for decode (tp2d was refuted — see
# EXPERIMENTS.md §Perf): classic 1-D Megatron TP on `model` only, no
# FSDP dim. Weights replicate across `data`; affordable only when
# quantized (q4_0: 110B x 0.5625B / 16 = 3.9 GB/chip), which is exactly
# the paper's Q4 lever applied at pod scale. Batch/KV stay on `data`,
# so the only collective is the per-layer row-parallel all-reduce.
RULES_TP1D = AxisRules("tp1d", {
    "batch": ("pod", "data"),
    "act_embed": None,
    "conv": None,
    "state": None,
    "expert_cap": ("pod", "data"),
    "seq": None,
    "kv_seq": "model",
    "embed": None,            # replicated input dim (no FSDP gathers)
    "mlp": "model",
    "heads": "model",
    "qkv_fused": "model",
    "vocab": "model",
    "expert": "model",
})

# v2 with experts sharded over BOTH mesh axes (hillclimb experiment:
# kimi-k2 has 384 experts = 1.5/chip at 256 chips; the token buffer
# then reshards once data->expert instead of scatter across model while
# batch-sharded on data).
RULES_V2E = AxisRules("v2e", {
    **RULES_V2.rules,
    "expert": ("data", "model"),
    "expert_cap": None,
})

# Hillclimb: v2 without sequence parallelism (activations replicated on
# seq). Tests whether the train-shape collective term is dominated by
# the seq@model <-> heads@model residual resharding per block.
RULES_V2NS = AxisRules("v2ns", {**RULES_V2.rules, "seq": None})

# v2e + no sequence parallelism (kimi iteration 3)
RULES_V2ENS = AxisRules("v2ens", {**RULES_V2E.rules, "seq": None})

_RULESETS = {"v0": RULES_V0, "v1": RULES_V1, "v2": RULES_V2,
             "v3": RULES_V3, "tp2d": RULES_TP2D, "tp1d": RULES_TP1D,
             "v2e": RULES_V2E, "v2ns": RULES_V2NS, "v2ens": RULES_V2ENS}


def rules_for(version: str) -> AxisRules:
    return _RULESETS[version]


def _filter_axes(axes: MeshAxes, mesh: Optional[Mesh]) -> MeshAxes:
    """Drop mesh axes that don't exist (e.g. no 'pod' on single-pod)."""
    if axes is None:
        return None
    names = mesh.axis_names if mesh is not None else ("pod", "data", "model")
    if isinstance(axes, str):
        return axes if axes in names else None
    kept = tuple(a for a in axes if a in names)
    return kept if kept else None


def logical_to_spec(logical: Sequence[Optional[str]], rules: AxisRules,
                    mesh: Optional[Mesh] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    used = set()
    out = []
    for ax in logical:
        phys = _filter_axes(rules.get(ax), mesh)
        # A mesh axis may appear at most once in a spec; later wins → None
        if phys is not None:
            flat = (phys,) if isinstance(phys, str) else phys
            if any(f in used for f in flat):
                phys = None
            else:
                used.update(flat)
        out.append(phys)
    # trim trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrain(x, logical: Sequence[Optional[str]],
              rules: Optional[AxisRules] = None,
              mesh: Optional[Mesh] = None):
    """with_sharding_constraint by logical axes (no-op off-mesh).

    Mesh and rules default to the ``repro.distributed.context`` values,
    so model code stays mesh-agnostic and runs unmodified on one device.
    """
    from repro.distributed import context as ctx
    env_mesh = mesh if mesh is not None else ctx.current_mesh()
    if env_mesh is None:
        return x
    if rules is None:
        rules = ctx.current_rules() or RULES_V2
    spec = logical_to_spec(logical, rules, env_mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env_mesh, spec))


def named_sharding(mesh: Mesh, logical: Sequence[Optional[str]],
                   rules: AxisRules) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, rules, mesh))


def sanitize_spec(shape: Sequence[int], spec: P, mesh: Mesh) -> P:
    """Drop spec entries whose mesh extent doesn't divide the dim.

    jit in_shardings require exact divisibility; odd vocabularies
    (50280, 256206) or batch=1 long-context shapes fall back to
    replication on that dim (GSPMD re-shards internally as needed).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        extent = 1
        for a in axes:
            extent *= sizes[a]
        out.append(entry if shape[i] % extent == 0 else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(abs_tree, pspec_tree, mesh: Mesh):
    """NamedShardings for a ShapeDtypeStruct tree, sanitized per leaf."""
    from repro.quant.quantize import QuantizedTensor

    def mk(leaf, spec):
        return NamedSharding(mesh, sanitize_spec(leaf.shape, spec, mesh))

    return jax.tree_util.tree_map(
        mk, abs_tree, pspec_tree,
        is_leaf=lambda x: (not isinstance(x, QuantizedTensor)
                           and hasattr(x, "shape") and hasattr(x, "dtype")))
