from repro.distributed.sharding import (
    AxisRules,
    RULES_V0,
    RULES_V1,
    RULES_V2,
    RULES_V3,
    rules_for,
    logical_to_spec,
    constrain,
)

__all__ = [
    "AxisRules", "RULES_V0", "RULES_V1", "RULES_V2", "RULES_V3",
    "rules_for", "logical_to_spec", "constrain",
]
