"""Explicit mesh/rules context (avoids deprecated ambient-mesh APIs).

Launchers do::

    with use_mesh(mesh), use_rules(rules):
        jax.jit(step, ...)

Model code calls :func:`repro.distributed.sharding.constrain`, which
reads this context; with no mesh set, constraints are no-ops so the same
model code runs single-device in tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

from jax.sharding import Mesh

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules():
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


@contextlib.contextmanager
def use_rules(rules):
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev
