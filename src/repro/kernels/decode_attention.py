"""Flash-decoding style single-token GQA attention over a KV cache.

The decode phase is the paper's primary regime (batch-1 token
generation, §5): one query token attends to a long cache. On TPU the
cache read is the memory-roofline term, so the kernel streams KV blocks
through VMEM once, keeping the (m, l, acc) online-softmax state in
scratch. The grouped queries for one KV head — shape (G, D), where
G = Hq/Hkv — are processed together, so K/V blocks are read exactly
once per KV head (GQA's entire point, paper §2.1).

Supports part-filled caches (kv_len per batch row) and sliding-window
caches (only the last ``window`` entries are valid, ring-buffer order
handled by the caller via kv_len masking).

Grid: (B, Hkv, S/bk). Block-skip for entries beyond kv_len.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 1024
NEG_INF = -1e30


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, window: int,
                   bk: int, kv_steps: int, out_dtype):
    b, j = pl.program_id(0), pl.program_id(2)
    kv_len = lens_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks entirely past the valid region / before the window
    lo_valid = kv_len - window if window else 0
    blk_visible = jnp.logical_and(j * bk < kv_len,
                                  (j + 1) * bk > lo_valid)

    @pl.when(blk_visible)
    def _body():
        # Mirror the XLA oracle (ops._decode_attention_jnp): the scaled
        # query and the probabilities round back to the input dtype,
        # and the dots run on input-dtype operands with f32
        # accumulation — so bf16 serving runs are token-identical
        # across kernel backends (all no-ops for f32 inputs).
        q = (q_ref[0, 0].astype(jnp.float32) * scale
             ).astype(q_ref.dtype)                       # (G, D)
        k = k_ref[0, 0].astype(q_ref.dtype)              # (bk, D)
        v = v_ref[0, 0].astype(q_ref.dtype)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if window:
            mask &= kpos >= kv_len - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(q_ref.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(out_dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len, *, window: int = 0,
                     scale: Optional[float] = None,
                     bk: int = DEFAULT_BK,
                     interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: (B,) int32."""
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(bk, S)
    assert S % bk == 0
    kv_steps = S // bk
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len, jnp.int32)

    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(
        _decode_kernel, scale=scale, window=window, bk=bk,
        kv_steps=kv_steps, out_dtype=q.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, kv_steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # kv_len
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qg, k, v)
    return out.reshape(B, Hq, D)
