"""Pure-jnp oracles for every Pallas kernel (allclose targets).

Each function is the mathematically-obvious implementation with no
tiling, used by tests/test_kernels.py to validate the Pallas kernels in
interpret mode across shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.quantize import QuantizedTensor, dequantize


def quant_matmul_ref(x: jax.Array, w: QuantizedTensor,
                     out_dtype=jnp.bfloat16) -> jax.Array:
    """x @ dequant(w): x (M, K) activation, w logical (K, N)."""
    wd = dequantize(w, jnp.float32)
    return jnp.dot(x.astype(jnp.float32), wd,
                   preferred_element_type=jnp.float32).astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  scale: Optional[float] = None,
                  q_offset: int = 0) -> jax.Array:
    """GQA attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (for decode: kv_len - Sq).
    ``window`` > 0: sliding window — key j visible to query i iff
    i - window < j <= i (positions absolute).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, g, axis=1)
    vf = jnp.repeat(vf, g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no visible keys (possible with tiny windows) -> 0
    probs = jnp.where(jnp.any(mask, -1)[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         kv_len: jax.Array | int,
                         window: int = 0) -> jax.Array:
    """Single-token GQA attention over a (possibly part-filled) cache.

    q: (B, Hq, D); k, v: (B, Hkv, S, D); kv_len: int or (B,) — number of
    valid cache entries per sequence (the new token's position is
    kv_len - 1, i.e. the cache already contains it).
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len)
    # key j valid iff j < kv_len and (no window or j >= kv_len - window)
    kpos = jnp.arange(S)[None, :]
    mask = kpos < kv_len[:, None]
    if window:
        mask &= kpos >= (kv_len[:, None] - window)
    g = Hq // Hkv
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=1)
    scores = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32) * D**-0.5, kf)
    scores = jnp.where(mask[:, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", probs, vf).astype(q.dtype)


def ssd_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                 B: jax.Array, C: jax.Array,
                 init_state: Optional[jax.Array] = None):
    """Mamba-2 SSD oracle: naive sequential recurrence.

    x:  (b, s, h, p)   inputs per head
    dt: (b, s, h)      positive step sizes (post-softplus)
    A:  (h,)           negative scalars per head
    B:  (b, s, n)      input projection (shared across heads)
    C:  (b, s, n)      output projection
    Returns (y (b,s,h,p), final_state (b,h,p,n)).

    Recurrence per head: S_t = exp(dt_t*A) * S_{t-1} + dt_t * x_t B_t^T
                         y_t = S_t C_t
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = B.astype(jnp.float32), C.astype(jnp.float32), A.astype(jnp.float32)
    S0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(S, t):
        decay = jnp.exp(dtf[:, t] * Af[None, :])          # (b, h)
        dBx = jnp.einsum("bh,bhp,bn->bhpn", dtf[:, t], xf[:, t], Bf[:, t])
        S = S * decay[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", S, Cf[:, t])
        return S, y

    S, ys = jax.lax.scan(step, S0, jnp.arange(s))
    y = jnp.moveaxis(ys, 0, 1)                            # (b, s, h, p)
    return y.astype(x.dtype), S


def rglru_ref(x: jax.Array, a: jax.Array, gate: jax.Array,
              init_state: Optional[jax.Array] = None):
    """RG-LRU oracle: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (g_t * x_t).

    x, a, gate: (b, s, w); a in (0, 1). Returns (y, final_state)."""
    xf = (x * gate).astype(jnp.float32)
    af = a.astype(jnp.float32)
    scale = jnp.sqrt(jnp.clip(1.0 - af ** 2, 0.0, None))
    h0 = (jnp.zeros(x.shape[:1] + x.shape[2:], jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def step(h, t):
        h = af[:, t] * h + scale[:, t] * xf[:, t]
        return h, h

    h, ys = jax.lax.scan(step, h0, jnp.arange(x.shape[1]))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
