"""Flash-decoding attention over a *quantized* KV cache (fused dequant).

PR 4 measured the q4_0 failure mode the paper's Fig 4e predicts:
streaming 0.281x the cache bytes but decoding at 0.75-0.81x bf16,
because ``kv_cache_read`` materializes a full dequantized bf16 cache
view every megastep — the dequant-bandwidth tax that dominates low-bit
formats on memory-bound decode. This kernel eliminates the unpack: it
reads the int8 payload + groupwise scales leaves directly and
dequantizes in-register inside the online-softmax block loop, so HBM
traffic stays at the quantized width (8.5/16 or 4.5/16 of bf16) and the
unpack cost is VREG shifts hidden under the cache stream.

Same grid and scratch layout as ``decode_attention.py``:
(B, Hkv, S/bk), online-softmax (m, l, acc) state in VMEM scratch,
grouped queries (G, D) per KV head. The q4_0 nibble-unpack
(mask/shift/sign-extend) is fused into the K/V block load; dequantized
values are rounded to bf16 before the dot so the kernel feeds the MXU
the exact values the XLA path (``dequantize_rows`` -> bf16 view) sees.

Payload layouts (see quant/quantize.py row-wise helpers):
  q8_0: k/v (B, Hkv, S, D) int8;      scales (B, Hkv, S, D//g) bf16
  q4_0: k/v (B, Hkv, S, D//2) int8 (two nibbles per byte, low = even
        feature index); scales as above. g = kv_group_size(D, group,
        fmt) — inferred here from the scales' last dim, so
        non-group-aligned head dims (any divisor group) just work.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 1024
NEG_INF = -1e30


def _dequant_rows(qblk: jax.Array, sblk: jax.Array, fmt: str) -> jax.Array:
    """In-register row-wise dequant of one (bk, D[/2]) cache block.

    Mirrors ``quant.quantize.dequantize_rows`` (incl. the bf16 rounding
    of its default out dtype) so the kernel is value-identical to the
    XLA unpack path; the dots below run on these bf16 values with f32
    accumulation, the same op the XLA oracle runs.
    """
    if fmt == "q4_0":
        lo = (qblk & 0x0F).astype(jnp.int8)
        hi = ((qblk >> 4) & 0x0F).astype(jnp.int8)
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        # interleave back to feature order: even idx = low nibble
        q = jnp.stack([lo, hi], axis=-1).reshape(
            qblk.shape[0], 2 * qblk.shape[1])
    else:
        q = qblk
    bk, d = q.shape
    g = d // sblk.shape[-1]
    qg = q.astype(jnp.float32).reshape(bk, d // g, g)
    x = qg * sblk.astype(jnp.float32)[..., None]
    return x.reshape(bk, d).astype(jnp.bfloat16)


def _decode_quant_kernel(lens_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, fmt: str,
                         scale: float, window: int, bk: int,
                         kv_steps: int, out_dtype):
    b, j = pl.program_id(0), pl.program_id(2)
    kv_len = lens_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    lo_valid = kv_len - window if window else 0
    blk_visible = jnp.logical_and(j * bk < kv_len,
                                  (j + 1) * bk > lo_valid)

    @pl.when(blk_visible)
    def _body():
        # Scaled q and p round to the input dtype, and the dots run on
        # input-dtype operands with f32 accumulation — exactly the ops
        # the XLA oracle (ops._decode_attention_jnp on a dequantized
        # bf16 view) runs, so bf16 serving is token-identical across
        # backends; no-ops for f32 inputs.
        q = (q_ref[0, 0].astype(jnp.float32) * scale
             ).astype(q_ref.dtype)                           # (G, D)
        k = _dequant_rows(kq_ref[0, 0], ks_ref[0, 0], fmt
                          ).astype(q_ref.dtype)              # (bk, D)
        v = _dequant_rows(vq_ref[0, 0], vs_ref[0, 0], fmt
                          ).astype(q_ref.dtype)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if window:
            mask &= kpos >= kv_len - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(q_ref.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(out_dtype)


def decode_attention_quant(q: jax.Array, k_q: jax.Array, k_scale: jax.Array,
                           v_q: jax.Array, v_scale: jax.Array, kv_len, *,
                           fmt: str, window: int = 0,
                           scale: Optional[float] = None,
                           bk: int = DEFAULT_BK,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k_q/v_q int8 payload (B, Hkv, S, D or D//2);
    k_scale/v_scale (B, Hkv, S, D//g); kv_len: (B,) int32."""
    if fmt not in ("q8_0", "q4_0"):
        raise ValueError(f"decode_attention_quant: fmt must be q8_0 or "
                         f"q4_0, got {fmt!r}")
    B, Hq, D = q.shape
    _, Hkv, S, Dp = k_q.shape
    if (D // 2 if fmt == "q4_0" else D) != Dp:
        raise ValueError(f"payload dim {Dp} inconsistent with head dim "
                         f"{D} under {fmt} (q {q.shape}, k_q {k_q.shape})")
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(bk, S)
    assert S % bk == 0
    kv_steps = S // bk
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len, jnp.int32)

    ng = k_scale.shape[-1]
    qg = q.reshape(B, Hkv, G, D)
    kernel = functools.partial(
        _decode_quant_kernel, fmt=fmt, scale=scale, window=window, bk=bk,
        kv_steps=kv_steps, out_dtype=q.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, kv_steps),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # kv_len
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, Dp), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, ng), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, Dp), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, ng), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qg, k_q, k_scale, v_q, v_scale)
    return out.reshape(B, Hq, D)
