"""Public jit'd kernel wrappers — the dispatch point between Pallas and XLA.

Models call these; the paper's hardware-aware planner (core/dispatch)
decides per-GEMM whether the Pallas path runs. On this CPU container
Pallas executes in interpret mode (``REPRO_PALLAS_INTERPRET=1`` default
when no TPU is present); on a real pod the same call sites compile to
Mosaic.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.quant.quantize import QuantizedTensor, dequantize
from repro.kernels import quant_matmul as _qmm
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() != "tpu"


def matmul(x: jax.Array, w: Union[jax.Array, QuantizedTensor], *,
           use_pallas: bool = False,
           out_dtype=None) -> jax.Array:
    """x @ w for plain or quantized weights.

    x may have leading batch dims; they are flattened into M. Without
    ``use_pallas``, quantized weights dequantize via XLA (still saves
    HBM for storage; in-kernel dequant needs the Pallas path).
    """
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    if isinstance(w, QuantizedTensor):
        N = w.logical_shape[-1]
        if use_pallas:
            x2 = x.reshape(-1, K)
            M = x2.shape[0]
            # tile sizes must divide; fall back to XLA when misaligned
            bm = _pick_tile(M, _qmm.DEFAULT_BM)
            bn = _pick_tile(N, _qmm.DEFAULT_BN)
            bk = _pick_tile(K, _qmm.DEFAULT_BK, multiple=w.group)
            if bm and bn and bk:
                out = _qmm.quant_matmul(
                    x2, w, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                    interpret=_interpret_default())
                return out.reshape(*lead, N)
        wd = dequantize(w, out_dtype)
        return jnp.dot(x, wd, preferred_element_type=jnp.float32
                       ).astype(out_dtype)
    return jnp.dot(x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def _pick_tile(dim: int, preferred: int, multiple: int = 1) -> Optional[int]:
    """Largest tile <= preferred that divides dim (and is a multiple)."""
    t = min(preferred, dim)
    while t >= multiple:
        if dim % t == 0 and t % multiple == 0:
            return t
        t -= multiple
    return None


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              q_offset: int = 0, use_pallas: bool = False,
              scale: Optional[float] = None) -> jax.Array:
    """Prefill/training attention; (B, H, S, D) layout."""
    if use_pallas:
        Sq, Skv = q.shape[2], k.shape[2]
        bq = _pick_tile(Sq, _fa.DEFAULT_BQ)
        bk = _pick_tile(Skv, _fa.DEFAULT_BK)
        if bq and bk:
            return _fa.flash_attention(
                q, k, v, causal=causal, window=window, scale=scale,
                q_offset=q_offset, bq=bq, bk=bk,
                interpret=_interpret_default())
    from repro.kernels import ref
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, kv_len, *,
                     window: int = 0, use_pallas: bool = False,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token attention; q (B, H, D), cache (B, Hkv, S, D)."""
    if use_pallas:
        S = k.shape[2]
        bk = _pick_tile(S, _da.DEFAULT_BK)
        if bk:
            return _da.decode_attention(
                q, k, v, kv_len, window=window, scale=scale, bk=bk,
                interpret=_interpret_default())
    return _decode_attention_jnp(q, k, v, kv_len, window=window,
                                 scale=scale)


def _decode_attention_jnp(q, k, v, kv_len, *, window: int = 0,
                          scale: Optional[float] = None) -> jax.Array:
    """bf16-preserving decode attention (the XLA production path).

    Deliberately avoids ``k.astype(f32)`` / ``v.astype(f32)``: inside a
    scan-over-layers, XLA hoists such elementwise converts out of the
    loop, materializing an f32 copy of the *entire stacked KV cache*
    (2x the cache in HBM). Mixed-precision matmuls with
    ``preferred_element_type=f32`` keep the cache read at bf16 width.
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len)
    # Barrier: stops XLA-CPU's bf16→f32 dot legalization converts from
    # being loop-hoisted over the whole stacked cache (2x HBM). No-op
    # on TPU where bf16 dots are native.
    k, v = jax.lax.optimization_barrier((k, v))
    qg = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qg = qg.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k,
                   preferred_element_type=jnp.float32)    # (B,Hkv,G,S)
    kpos = jnp.arange(S)[None, :]
    mask = kpos < kv_len[:, None]
    if window:
        mask &= kpos >= kv_len[:, None] - window
    s = jnp.where(mask[:, None, None], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-20)
    return out.reshape(B, Hq, D).astype(q.dtype)
