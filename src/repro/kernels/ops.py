"""Public jit'd kernel wrappers — the dispatch point between Pallas and XLA.

Models call these; the paper's hardware-aware planner (core/dispatch)
decides per-GEMM whether the Pallas path runs. On this CPU container
Pallas executes in interpret mode (``REPRO_PALLAS_INTERPRET=1`` default
when no TPU is present); on a real pod the same call sites compile to
Mosaic.
"""
from __future__ import annotations

import functools
import math
import os
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.quant.quantize import QuantizedTensor, dequantize, dequantize_rows
from repro.kernels import quant_matmul as _qmm
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import decode_attention_quant as _daq

# MXU/VREG lane width: the minor tile dim of any Mosaic-compiled
# operand must be a multiple of this (sublane dims only need 8).
LANE = 128
SUBLANE = 8


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off", "")
    return jax.default_backend() != "tpu"


def matmul(x: jax.Array, w: Union[jax.Array, QuantizedTensor], *,
           use_pallas: bool = False,
           out_dtype=None) -> jax.Array:
    """x @ w for plain or quantized weights.

    x may have leading batch dims; they are flattened into M. Without
    ``use_pallas``, quantized weights dequantize via XLA (still saves
    HBM for storage; in-kernel dequant needs the Pallas path).
    """
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    K = x.shape[-1]
    if isinstance(w, QuantizedTensor):
        N = w.logical_shape[-1]
        if use_pallas:
            x2 = x.reshape(-1, K)
            M = x2.shape[0]
            # Tile sizes must divide, and the lane dims (bn, and bk —
            # the minor dim of the activation block) must be
            # 128-aligned or span their whole dim, else Mosaic won't
            # compile them; misaligned shapes fall back to XLA. bm is
            # the sublane dim: 8-aligned when M allows, else bm = M
            # (< 8) and Mosaic pads the sublanes — that keeps M=1..7
            # decode GEMVs on the fused path instead of the old
            # degenerate bm=1 tiling of large M.
            bm = M if M < SUBLANE else _pick_tile(M, _qmm.DEFAULT_BM,
                                                  multiple=SUBLANE)
            bn = _pick_lane_tile(N, _qmm.DEFAULT_BN)
            bk = _pick_lane_tile(K, _qmm.DEFAULT_BK, multiple=w.group)
            if bm and bn and bk:
                out = _qmm.quant_matmul(
                    x2, w, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                    interpret=_interpret_default())
                return out.reshape(*lead, N)
        wd = dequantize(w, out_dtype)
        # Barrier: pin the dot's operands to their materialized
        # activation-dtype values. Inside a fused jit graph XLA-CPU
        # otherwise feeds the dot *unrounded* f32 activations (the
        # bf16 cast upstream is elided as excess precision), while the
        # Pallas path always reads rounded bf16 through the
        # pallas_call boundary — the two backends would then disagree
        # at the last ulp and greedy token streams could flip. Same
        # trick as _decode_attention_jnp's cache barrier below.
        x, wd = jax.lax.optimization_barrier((x, wd))
        return jnp.dot(x, wd, preferred_element_type=jnp.float32
                       ).astype(out_dtype)
    return jnp.dot(x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def _pick_tile(dim: int, preferred: int, multiple: int = 1) -> Optional[int]:
    """Largest tile <= preferred that divides dim and is a multiple of
    ``multiple``. Returns None when no such tile exists — callers fall
    back to the XLA path rather than hand Mosaic a misaligned tile
    (e.g. bn=29 for a prime-factor dim, which only "works" in interpret
    mode)."""
    t = (min(preferred, dim) // multiple) * multiple
    while t >= multiple:
        if dim % t == 0:
            return t
        t -= multiple
    return None


def _pick_lane_tile(dim: int, preferred: int,
                    multiple: int = 1) -> Optional[int]:
    """Tile for a 128-lane minor dim: a 128-aligned divisor, or the
    full dim when it fits in one 8-aligned block (Mosaic pads a
    full-span minor dim to the lane width; it cannot *partition* a dim
    into misaligned tiles). None → XLA fallback."""
    t = _pick_tile(dim, preferred, multiple=_lcm(multiple, LANE))
    if t:
        return t
    if dim <= preferred and dim % _lcm(multiple, SUBLANE) == 0:
        return dim          # single full-span block, lane-padded
    return None


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int = 0,
              q_offset: int = 0, use_pallas: bool = False,
              scale: Optional[float] = None) -> jax.Array:
    """Prefill/training attention; (B, H, S, D) layout."""
    if use_pallas:
        Sq, Skv = q.shape[2], k.shape[2]
        bq = _pick_tile(Sq, _fa.DEFAULT_BQ)
        bk = _pick_tile(Skv, _fa.DEFAULT_BK)
        if bq and bk:
            return _fa.flash_attention(
                q, k, v, causal=causal, window=window, scale=scale,
                q_offset=q_offset, bq=bq, bk=bk,
                interpret=_interpret_default())
    from repro.kernels import ref
    return ref.attention_ref(q, k, v, causal=causal, window=window,
                             scale=scale, q_offset=q_offset)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, kv_len, *,
                     window: int = 0, use_pallas: bool = False,
                     scale: Optional[float] = None) -> jax.Array:
    """Single-token attention; q (B, H, D), cache (B, Hkv, S, D)."""
    if use_pallas:
        S = k.shape[2]
        bk = _pick_tile(S, _da.DEFAULT_BK)
        if bk:
            return _da.decode_attention(
                q, k, v, kv_len, window=window, scale=scale, bk=bk,
                interpret=_interpret_default())
    return _decode_attention_jnp(q, k, v, kv_len, window=window,
                                 scale=scale)


def decode_attention_quant(q: jax.Array, k_q: jax.Array,
                           k_scale: jax.Array, v_q: jax.Array,
                           v_scale: jax.Array, kv_len, *, fmt: str,
                           window: int = 0, use_pallas: bool = False,
                           scale: Optional[float] = None) -> jax.Array:
    """Decode attention straight off quantized KV-cache leaves.

    q: (B, Hq, D); k_q/v_q int8 payload (B, Hkv, S, D) [q8_0] or
    (B, Hkv, S, D//2) [q4_0]; k_scale/v_scale (B, Hkv, S, D//g).

    The Pallas path dequantizes in-register inside the online-softmax
    block loop — HBM reads stay at the quantized width. The XLA
    fallback is computation-identical to the pre-fusion production
    path: materialize a bf16 view (``dequantize_rows``) and run
    ``_decode_attention_jnp`` on it.
    """
    if use_pallas:
        S = k_q.shape[2]
        bk = _pick_tile(S, _daq.DEFAULT_BK)
        if bk:
            return _daq.decode_attention_quant(
                q, k_q, k_scale, v_q, v_scale, kv_len, fmt=fmt,
                window=window, scale=scale, bk=bk,
                interpret=_interpret_default())
    k = dequantize_rows(k_q, k_scale, fmt)
    v = dequantize_rows(v_q, v_scale, fmt)
    return _decode_attention_jnp(q, k, v, kv_len, window=window,
                                 scale=scale)


def _decode_attention_jnp(q, k, v, kv_len, *, window: int = 0,
                          scale: Optional[float] = None) -> jax.Array:
    """bf16-preserving decode attention (the XLA production path).

    Deliberately avoids ``k.astype(f32)`` / ``v.astype(f32)``: inside a
    scan-over-layers, XLA hoists such elementwise converts out of the
    loop, materializing an f32 copy of the *entire stacked KV cache*
    (2x the cache in HBM). Mixed-precision matmuls with
    ``preferred_element_type=f32`` keep the cache read at bf16 width.
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    kv_len = jnp.asarray(kv_len)
    if kv_len.ndim == 0:
        kv_len = jnp.full((B,), kv_len)
    # Barrier: stops XLA-CPU's bf16→f32 dot legalization converts from
    # being loop-hoisted over the whole stacked cache (2x HBM). No-op
    # on TPU where bf16 dots are native.
    k, v = jax.lax.optimization_barrier((k, v))
    qg = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qg = qg.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k,
                   preferred_element_type=jnp.float32)    # (B,Hkv,G,S)
    kpos = jnp.arange(S)[None, :]
    mask = kpos < kv_len[:, None]
    if window:
        mask &= kpos >= kv_len[:, None] - window
    s = jnp.where(mask[:, None, None], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-20)
    return out.reshape(B, Hq, D).astype(q.dtype)
