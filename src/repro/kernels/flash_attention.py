"""Tiled online-softmax (flash) attention, GQA + causal + sliding window.

TPU adaptation notes (DESIGN.md §2): the paper identifies attention's
``kq``/``kqv`` matmuls as part of the MUL_MAT bottleneck; FlashAttention
is cited (§2.1) as the standard remedy. This kernel tiles Q and KV into
VMEM blocks, keeps the running (m, l, acc) statistics in VMEM scratch
across the KV grid dimension, and *skips* KV blocks that are fully
masked by causality or the sliding window — the block-skip is what makes
``long_500k`` prefill linear-in-window rather than quadratic for the
windowed dense architectures.

Grid: (B, Hq, Sq/bq, Skv/bk), KV innermost. GQA is handled in the index
map: query head h reads KV head h // (Hq // Hkv).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 512
DEFAULT_BK = 512
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  q_offset: int, bq: int, bk: int, kv_steps: int,
                  out_dtype):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-skip: is any (qpos, kpos) pair in this tile visible?
    q_lo = i * bq + q_offset          # absolute position of first query
    q_hi = q_lo + bq - 1
    k_lo = j * bk
    k_hi = k_lo + bk - 1
    visible = True
    if causal:
        visible = jnp.asarray(k_lo <= q_hi)
    if window:
        visible = jnp.logical_and(visible, k_hi > q_lo - window)

    @pl.when(visible if (causal or window) else True)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(out_dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None, q_offset: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """GQA flash attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    kv_steps = Skv // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bk=bk, kv_steps=kv_steps,
        out_dtype=q.dtype)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, Sq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
