"""W8A16 / W4A16 groupwise dequant-in-VMEM matmul (Pallas TPU).

The paper's profiling shows GEMM is 87.6%/76.2% of inference time and
that Q4 quantization is the single largest lever (§5.3). On TPU the
equivalent design is: keep weights in HBM at 4.5/8.5 bits, stream the
*quantized* blocks into VMEM, dequantize there (VREG shifts + one
multiply per group) and feed the MXU with bf16 tiles. HBM traffic drops
by the quantization ratio — exactly the memory-roofline win the paper
measures on the A17's DRAM bus.

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulator
tile lives in VMEM scratch across the K loop. ``bk`` must be a multiple
of the quant group (32). Lane alignment is *not* assumed here: the
dispatch layer (``ops.matmul`` → ``_pick_lane_tile``) enforces that the
lane dims bn/bk are 128-aligned or span their whole dimension, and the
sublane dim bm is 8-aligned when M >= 8 (bm = M below that — Mosaic
pads sublanes for small decode GEMVs); shapes with no such tiling fall
back to the XLA dequant path instead of reaching this kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.quantize import QuantizedTensor

DEFAULT_BM = 256
DEFAULT_BN = 256
DEFAULT_BK = 512


def _dequant_block_q8(qblk, sblk, group):
    bk, bn = qblk.shape
    q = qblk.astype(jnp.float32).reshape(bk // group, group, bn)
    return (q * sblk.astype(jnp.float32)[:, None, :]).reshape(bk, bn)


def _dequant_block_q4(qblk, sblk, group):
    # qblk packed: (bk//2, bn) int8, two nibbles per byte
    lo = (qblk & 0x0F).astype(jnp.int8)
    hi = ((qblk >> 4) & 0x0F).astype(jnp.int8)
    lo = jnp.where(lo > 7, lo - 16, lo)
    hi = jnp.where(hi > 7, hi - 16, hi)
    k2, bn = qblk.shape
    q = jnp.stack([lo, hi], axis=1).reshape(2 * k2, bn)  # interleaved
    q = q.astype(jnp.float32).reshape(2 * k2 // group, group, bn)
    return (q * sblk.astype(jnp.float32)[:, None, :]).reshape(2 * k2, bn)


def _qmm_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, fmt: str,
                group: int, k_steps: int, out_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if fmt == "q8_0":
        w = _dequant_block_q8(q_ref[...], s_ref[...], group)
    else:
        w = _dequant_block_q4(q_ref[...], s_ref[...], group)
    # Round the dequantized tile to the activation dtype and feed the
    # MXU an activation-dtype x activation-dtype dot with f32
    # accumulation — the exact op ops.matmul's XLA fallback runs on
    # dequantize(w, out_dtype), so the backends are token-identical
    # (not merely allclose) for bf16 serving.
    acc_ref[...] += jax.lax.dot(x, w.astype(x.dtype),
                                preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def quant_matmul(x: jax.Array, w: QuantizedTensor, *,
                 bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 bk: int = DEFAULT_BK,
                 out_dtype=jnp.bfloat16,
                 interpret: bool = False) -> jax.Array:
    """``x @ dequant(w)`` with in-kernel dequantization.

    x: (M, K) activation; w: logical (K, N) in q8_0 (data (K, N) int8)
    or q4_0 (data (K//2, N) packed int8); scales (K//group, N).
    """
    M, K = x.shape
    Kw, N = w.logical_shape[-2:]
    if K != Kw:
        raise ValueError(
            f"quant_matmul: reduction-dim mismatch — x has K={K} "
            f"(shape {x.shape}) but weight has K={Kw} "
            f"(logical shape {w.logical_shape})")
    group = w.group
    bm = min(bm, M)
    bn = min(bn, N)
    bk = min(bk, K)
    if K % bk or bk % group:
        raise ValueError(
            f"quant_matmul: bk={bk} must divide K={K} and be a multiple "
            f"of the quant group {group} (x {x.shape}, w "
            f"{w.logical_shape} {w.fmt})")
    if M % bm or N % bn:
        raise ValueError(
            f"quant_matmul: tiles bm={bm}, bn={bn} must divide "
            f"M={M}, N={N} (x {x.shape}, w {w.logical_shape} {w.fmt})")
    k_steps = K // bk
    packed = w.fmt == "q4_0"
    kdiv = 2 if packed else 1

    kernel = functools.partial(
        _qmm_kernel, fmt=w.fmt, group=group, k_steps=k_steps,
        out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // kdiv, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w.data, w.scales)
