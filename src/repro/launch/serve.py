"""Serving launcher: bring up the continuous-batching engine on a
reduced (or full, on a real pod) model and run a synthetic request
stream.

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --reduced --requests 8 --precision q8_0
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.quant import quantize_tree
from repro.serving import Request, SamplingConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--precision", default="bf16",
                    choices=["bf16", "q8_0", "q4_0"])
    ap.add_argument("--kv-quant", dest="kv_quant", default="bf16",
                    choices=["bf16", "q8_0", "q4_0"],
                    help="KV-cache precision: groupwise int8 payload + "
                         "scale leaves per cached position (no-op for "
                         "ssm/hybrid state)")
    ap.add_argument("--kernels", default="",
                    choices=["", "xla", "pallas"],
                    help="kernel backend for quantized decode GEMVs + "
                         "quantized-cache attention reads (default: "
                         "derive from the config's use_pallas)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--megastep-k", type=int, default=None,
                    help="decode tokens per fused dispatch "
                         "(default: engine's DEFAULT_MEGASTEP_K)")
    ap.add_argument("--admission", default="chunked",
                    choices=["chunked", "stall"],
                    help="prompt admission: ride inside the megastep "
                         "scan (chunked) or batched prefill dispatches "
                         "between megasteps (stall)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="on-device prompt chunk size for chunked "
                         "admission (default: max(megastep_k, 16))")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable cache/SlotState buffer donation into "
                         "the megastep (doubles carry HBM traffic)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, quant_policy=args.precision,
                              kv_quant=args.kv_quant)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), quantize=False)
    if args.precision != "bf16":
        params = quantize_tree(params, args.precision)

    engine = ServingEngine(model, params, slots=args.slots,
                           max_len=args.max_len,
                           sampling=SamplingConfig(temperature=0.8,
                                                   top_k=40),
                           megastep_k=args.megastep_k,
                           admission=args.admission,
                           prefill_chunk=args.prefill_chunk,
                           donate_carries=not args.no_donate,
                           kernels=args.kernels or None)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size, size=4 + i % 5).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    engine.run()
    dt = time.time() - t0
    admit = (f"{engine.stats.inscan_admissions} in-scan admissions, "
             f"{engine.stats.chunk_refills} chunk refills"
             if engine.admission == "chunked" else
             f"{engine.stats.prefill_batches} prefill batches")
    print(f"arch={cfg.name} precision={args.precision} "
          f"kv_quant={engine.kv_quant} kernels={engine.kernels} "
          f"admission={engine.admission}: "
          f"{engine.stats.tokens_generated} tokens / {dt:.1f}s = "
          f"{engine.stats.tokens_generated / dt:.1f} tok/s "
          f"({engine.stats.steps} decode steps in "
          f"{engine.stats.megasteps} dispatches [K={engine.megastep_k}], "
          f"{engine.stats.prefills} prefills: {admit})")


if __name__ == "__main__":
    main()
