"""Serving launcher + actor-style asyncio front-end.

Two layers live here:

``AsyncServingFrontend``
    An asyncio actor wrapped around :class:`ServingEngine`. One
    background coroutine owns the engine and alternates between
    ``engine.step()`` (run in a thread-pool executor so the event
    loop stays live while the device computes) and a between-steps
    housekeeping pass that admits newly submitted requests, enforces
    per-request deadlines via ``engine.cancel`` (the frozen-write
    retirement path — the slot goes PHASE_IDLE and any in-flight
    megastep leaves its cache untouched), and streams freshly drained
    tokens to per-request callbacks.  All engine mutation happens on
    that one coroutine, so no locking is needed; ``generate()``
    merely stages work and awaits a future.  A semaphore bounds the
    number of admitted-but-unfinished requests (backpressure): when
    ``max_pending`` requests are in flight, new ``generate()`` calls
    suspend until a slot of the bound frees up.

CLI (``main``)
    Brings up the engine on a reduced (or full) model and runs a
    synthetic request stream, either synchronously or — with
    ``--frontend`` — through the asyncio front-end with staggered
    arrivals and optional deadlines.  Reported tok/s excludes jit
    compile: a warmup request pays compilation, ``engine.reset()``
    clears the stats (compiled executables survive), and the timed
    run reports decode tok/s from ``EngineStats.decode_wall_s`` with
    the warmup/compile split printed separately.

  PYTHONPATH=src python -m repro.launch.serve --arch mistral-nemo-12b \
      --no-reduced --requests 8 --precision q8_0 --pipeline-depth 2
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.quant import quantize_tree
from repro.serving import (QueueFull, Request, SamplingConfig,
                           ServingEngine, SubmitReject)


class DeadlineExceeded(Exception):
    """Raised by ``AsyncServingFrontend.generate`` when a request's
    deadline expires before it finishes. ``.tokens`` carries the
    partial output generated before cancellation."""

    def __init__(self, uid: int, tokens: List[int]):
        super().__init__(
            f"request {uid} cancelled at deadline after "
            f"{len(tokens)} token(s)")
        self.uid = uid
        self.tokens = tokens


class Backpressure(Exception):
    """Raised by ``generate`` when the engine sheds the request at its
    queue bound (``QueueFull``). ``retry_after_s`` is the predicted
    backlog drain time — from the engine's measured substep rate when
    it has one, else the front-end's ``drain_hint_s`` (seeded from
    ``dispatch.plan``'s predicted decode rate) scaled by queue depth.
    Callers should back off ~that long before resubmitting."""

    def __init__(self, uid: int, retry_after_s: Optional[float],
                 queue_depth: int):
        hint = (f"retry after ~{retry_after_s:.3f}s"
                if retry_after_s is not None else "retry after a drain")
        super().__init__(
            f"request {uid} shed at queue bound "
            f"(depth {queue_depth}); {hint}")
        self.uid = uid
        self.retry_after_s = retry_after_s
        self.queue_depth = queue_depth


class RequestFailed(Exception):
    """Raised by ``generate`` when the engine error-retires the
    request (e.g. ``nonfinite-logits`` from the in-scan finiteness
    check). ``.tokens`` carries output generated before the failure;
    co-batched requests are unaffected."""

    def __init__(self, uid: int, reason: str, tokens: List[int]):
        super().__init__(
            f"request {uid} failed: {reason} "
            f"(after {len(tokens)} token(s))")
        self.uid = uid
        self.reason = reason
        self.tokens = tokens


@dataclasses.dataclass
class _Handle:
    req: Request
    future: asyncio.Future
    on_token: Optional[Callable[[int], None]]
    deadline: Optional[float]        # absolute time.monotonic() deadline
    sent: int = 0                    # tokens already streamed
    admitted: bool = False           # engine.submit() has run
    expired: bool = False            # cancelled by the deadline sweep


class AsyncServingFrontend:
    """Actor-style asyncio front-end over a :class:`ServingEngine`.

    Usage::

        fe = AsyncServingFrontend(engine, max_pending=32)
        toks = await fe.generate(prompt, max_new_tokens=16,
                                 deadline_s=0.5, on_token=print)
        await fe.close()

    ``generate`` resolves with the full token list, raises
    :class:`DeadlineExceeded` (carrying partial tokens) on deadline
    expiry, raises :class:`Backpressure` (with a retry-after hint)
    when the engine sheds the request at its ``max_queue`` bound,
    raises :class:`RequestFailed` when the engine error-retires it
    (nonfinite logits), and propagates ``ValueError`` for requests the
    engine rejects at ``submit()`` (empty prompt, negative budget,
    ``PromptTooLong``). Cancelling the awaiting asyncio task cancels
    the request in the engine too — the slot retires via the same
    frozen-write path.

    ``drain_hint_s`` seeds the backpressure retry-after estimate (per
    queued request) before the engine has measured its own substep
    rate — pass ``dispatch.plan``'s predicted seconds-per-request.
    """

    def __init__(self, engine: ServingEngine, *, max_pending: int = 32,
                 drain_hint_s: Optional[float] = None):
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1 (got {max_pending})")
        self.engine = engine
        self.max_pending = max_pending
        self.drain_hint_s = drain_hint_s
        self._sem = asyncio.Semaphore(max_pending)
        self._incoming: List[_Handle] = []   # staged, not yet submitted
        self._live: List[_Handle] = []       # submitted, future pending
        self._to_cancel: List[_Handle] = []  # staged explicit cancels
        self._wake: Optional[asyncio.Event] = None
        self._task: Optional[asyncio.Task] = None
        self._closed = False
        self._uid = 0

    # -- public API ---------------------------------------------------

    async def generate(self, prompt, *, max_new_tokens: int = 32,
                       eos_id: int = -1,
                       temperature: Optional[float] = None,
                       top_k: Optional[int] = None,
                       top_p: Optional[float] = None,
                       deadline_s: Optional[float] = None,
                       on_token: Optional[Callable[[int], None]] = None,
                       ) -> List[int]:
        if self._closed:
            raise RuntimeError("front-end is closed")
        await self._sem.acquire()        # backpressure bound
        loop = asyncio.get_running_loop()
        self._ensure_loop()
        self._uid += 1
        req = Request(uid=self._uid,
                      prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, eos_id=eos_id,
                      temperature=temperature, top_k=top_k, top_p=top_p)
        handle = _Handle(
            req=req, future=loop.create_future(), on_token=on_token,
            deadline=(time.monotonic() + deadline_s
                      if deadline_s is not None else None))
        self._incoming.append(handle)
        self._wake.set()
        try:
            return await handle.future
        except asyncio.CancelledError:
            # caller bailed: retire the request's slot between steps
            self._to_cancel.append(handle)
            self._wake.set()
            raise

    async def close(self) -> None:
        """Stop the serve loop once staged work has drained."""
        self._closed = True
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None

    # -- serve loop ---------------------------------------------------

    def _ensure_loop(self) -> None:
        if self._wake is None:
            self._wake = asyncio.Event()
        if self._task is None or self._task.done():
            self._task = asyncio.get_running_loop().create_task(
                self._serve())

    def _admit_incoming(self) -> None:
        staged, self._incoming = self._incoming, []
        for h in staged:
            try:
                self.engine.submit(h.req)
                h.admitted = True
                self._live.append(h)
            except QueueFull as e:       # shed: surface backpressure
                retry = e.retry_after_s
                if retry is None and self.drain_hint_s is not None:
                    retry = self.drain_hint_s * max(e.queue_depth, 1)
                if not h.future.done():
                    h.future.set_exception(Backpressure(
                        h.req.uid, retry, e.queue_depth))
                self._sem.release()
            except ValueError as e:      # rejected at admission
                if not h.future.done():
                    h.future.set_exception(e)
                self._sem.release()

    def _sweep_cancellations(self) -> None:
        staged, self._to_cancel = self._to_cancel, []
        for h in staged:
            self.engine.cancel(h.req)
        now = time.monotonic()
        for h in self._live:
            if (h.deadline is not None and now >= h.deadline
                    and not h.req.done):
                h.expired = True
                self.engine.cancel(h.req)

    def _publish(self) -> None:
        still = []
        for h in self._live:
            fresh = h.req.output[h.sent:]
            h.sent += len(fresh)
            if h.on_token is not None:
                for tok in fresh:
                    h.on_token(tok)
            if not h.req.done:
                still.append(h)
                continue
            if not h.future.done():
                if h.expired:
                    h.future.set_exception(DeadlineExceeded(
                        h.req.uid, list(h.req.output)))
                elif h.req.error is not None:
                    h.future.set_exception(RequestFailed(
                        h.req.uid, h.req.error, list(h.req.output)))
                else:
                    h.future.set_result(list(h.req.output))
            self._sem.release()
        self._live = still

    async def _serve(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._admit_incoming()
            self._sweep_cancellations()
            self._publish()
            if not self.engine.has_work() and not self._live:
                if self._closed and not self._incoming:
                    return
                self._wake.clear()
                if not self._incoming and not self._to_cancel:
                    await self._wake.wait()
                continue
            # the event loop stays live while the engine steps: new
            # generate() calls stage work that the next iteration of
            # this loop admits between steps.
            await loop.run_in_executor(None, self.engine.step)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink the config for smoke runs "
                         "(--no-reduced for the paper-size model)")
    ap.add_argument("--precision", default="bf16",
                    choices=["bf16", "q8_0", "q4_0"])
    ap.add_argument("--kv-quant", dest="kv_quant", default="bf16",
                    choices=["bf16", "q8_0", "q4_0"],
                    help="KV-cache precision: groupwise int8 payload + "
                         "scale leaves per cached position (no-op for "
                         "ssm/hybrid state)")
    ap.add_argument("--kernels", default="",
                    choices=["", "xla", "pallas"],
                    help="kernel backend for quantized decode GEMVs + "
                         "quantized-cache attention reads (default: "
                         "derive from the config's use_pallas)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--megastep-k", type=int, default=None,
                    help="decode tokens per fused dispatch "
                         "(default: engine's DEFAULT_MEGASTEP_K)")
    ap.add_argument("--admission", default="chunked",
                    choices=["chunked", "stall"],
                    help="prompt admission: ride inside the megastep "
                         "scan (chunked) or batched prefill dispatches "
                         "between megasteps (stall)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="on-device prompt chunk size for chunked "
                         "admission (default: max(megastep_k, 16))")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable cache/SlotState buffer donation into "
                         "the megastep (doubles carry HBM traffic)")
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="megasteps kept in flight: 1 = serial "
                         "dispatch/drain, 2 = double-buffered (drain N "
                         "overlaps device megastep N+1)")
    # Paging pays when traffic shares prompt prefixes (the prefix
    # cache skips re-prefilling shared pages) or when the dense
    # slots*max_len prealloc overshoots live tokens; it costs a
    # per-step gather of the block table, so leave it off for
    # short-context, no-reuse streams. dispatch.plan's page_size knob
    # (fed by scheduler.simulate_paging) makes the same call
    # analytically.
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV-cache page size in tokens; 0 = dense "
                         "slot-major cache (no paging)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="reuse full prompt-prefix pages across "
                         "requests via content hashing (requires "
                         "--page-size > 0 and chunked admission)")
    ap.add_argument("--frontend", action="store_true",
                    help="route the synthetic stream through the "
                         "asyncio front-end (staggered arrivals, "
                         "streaming callbacks) instead of engine.run()")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline for --frontend runs")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bound the engine's admission queue: submit() "
                         "past this depth raises QueueFull (load "
                         "shedding; the front-end surfaces it as "
                         "Backpressure with a retry-after hint). "
                         "0 = unbounded")
    ap.add_argument("--audit", action="store_true",
                    help="run engine.audit() after every step (block-"
                         "pool partition, refcounts, slot/queue "
                         "invariants) — cheap host-side checks; raises "
                         "EngineAuditError on the first violation")
    return ap


def _make_requests(cfg, n: int, max_new: int,
                   shared_prefix: int = 0) -> List[Request]:
    rng = np.random.default_rng(0)
    shared = rng.integers(1, cfg.vocab_size,
                          size=shared_prefix).astype(np.int32)
    return [Request(uid=i,
                    prompt=np.concatenate([shared, rng.integers(
                        1, cfg.vocab_size,
                        size=4 + i % 5).astype(np.int32)]),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run_frontend(engine: ServingEngine, cfg, args) -> int:
    """Drive the synthetic stream through the asyncio front-end.
    Returns the number of deadline-expired requests."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=4 + i % 5)
               .astype(np.int32) for i in range(args.requests)]

    async def drive():
        fe = AsyncServingFrontend(engine,
                                  max_pending=max(2 * args.slots, 4))

        async def one(p):
            try:
                await fe.generate(p, max_new_tokens=args.max_new,
                                  deadline_s=args.deadline_s)
                return 0
            except DeadlineExceeded:
                return 1
            except (Backpressure, RequestFailed):
                return 1                 # shed or error-retired

        tasks = []
        for p in prompts:
            tasks.append(asyncio.ensure_future(one(p)))
            await asyncio.sleep(0)       # staggered arrivals
        expired = sum(await asyncio.gather(*tasks))
        await fe.close()
        return expired

    return asyncio.run(drive())


def main(argv: Optional[List[str]] = None) -> None:
    args = build_parser().parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, quant_policy=args.precision,
                              kv_quant=args.kv_quant)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0), quantize=False)
    if args.precision != "bf16":
        params = quantize_tree(params, args.precision)

    engine = ServingEngine(model, params, slots=args.slots,
                           max_len=args.max_len,
                           sampling=SamplingConfig(temperature=0.8,
                                                   top_k=40),
                           megastep_k=args.megastep_k,
                           admission=args.admission,
                           prefill_chunk=args.prefill_chunk,
                           donate_carries=not args.no_donate,
                           kernels=args.kernels or None,
                           pipeline_depth=args.pipeline_depth,
                           page_size=args.page_size,
                           prefix_cache=args.prefix_cache,
                           max_queue=args.max_queue)
    engine.audit_every_step = args.audit

    # Warmup pays jit compile; reset() keeps the compiled executables
    # but zeroes the stats so the timed run is compile-excluded (the
    # ROADMAP bench-methodology note: never fold compile into tok/s).
    t0 = time.perf_counter()
    engine.submit(Request(uid=-1,
                          prompt=np.arange(1, 6, dtype=np.int32),
                          max_new_tokens=max(args.max_new, 1)))
    engine.run()
    warmup_s = time.perf_counter() - t0
    engine.reset()

    t0 = time.perf_counter()
    expired = 0
    if args.frontend:
        expired = _run_frontend(engine, cfg, args)
    else:
        # with --prefix-cache, open every prompt with the same "system
        # prompt" (2 full pages + 1) so the shared pages actually hit
        shared = (2 * args.page_size + 1
                  if args.prefix_cache and args.page_size else 0)
        for r in _make_requests(cfg, args.requests, args.max_new,
                                shared_prefix=shared):
            try:
                engine.submit(r)
            except SubmitReject:
                # typed shed (QueueFull under --max-queue): counted in
                # stats.shed and reported below, never fatal — the CLI
                # submits its whole batch upfront, so a bounded queue
                # legitimately refuses the overflow
                pass
        engine.run()
    wall = time.perf_counter() - t0

    st = engine.stats
    decode_s = max(st.decode_wall_s, 1e-9)
    admit = (f"{st.inscan_admissions} in-scan admissions, "
             f"{st.chunk_refills} chunk refills"
             if engine.admission == "chunked" else
             f"{st.prefill_batches} prefill batches")
    print(f"arch={cfg.name} precision={args.precision} "
          f"kv_quant={engine.kv_quant} kernels={engine.kernels} "
          f"admission={engine.admission} depth={engine.pipeline_depth} "
          f"page_size={engine.page_size}: "
          f"{st.tokens_generated} tokens / {decode_s:.2f}s decode = "
          f"{st.tokens_generated / decode_s:.1f} tok/s "
          f"(warmup+compile {warmup_s:.1f}s excluded; run wall "
          f"{wall:.2f}s; {st.steps} decode steps in "
          f"{st.megasteps} dispatches [K={engine.megastep_k}], "
          f"{st.prefills} prefills: {admit}; "
          f"drain-wait {st.drain_wait_s:.3f}s)")
    if st.shed or st.preemptions or st.poisoned:
        print(f"overload: {st.shed} shed, {st.preemptions} "
              f"preemptions, {st.poisoned} poisoned-retired "
              f"(queue bound {engine.max_queue or 'none'})")
    if engine.page_size:
        print(f"paging: {engine.cache_blocks} blocks x "
              f"{engine.page_size} tokens, {engine.blocks_in_use} "
              f"blocks live after drain, {st.prefix_hits} prefix "
              f"hits ({st.prefix_hit_tokens} prompt tokens skipped)")
    if args.frontend:
        print(f"frontend: {args.requests - expired} completed, "
              f"{expired} deadline-expired, "
              f"{st.cancelled} engine cancellations")


if __name__ == "__main__":
    main()
