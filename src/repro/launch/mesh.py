"""Production mesh construction (deliverable e, step 1).

A function — not a module-level constant — so importing this module
never touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh() -> Mesh:
    """1x1 mesh on the real local device (smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
