"""Production mesh construction (deliverable e, step 1).

A function — not a module-level constant — so importing this module
never touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
jax import; smoke tests and benches see the real single device.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4.x; Auto is the old default anyway
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    _AXIS_KW = lambda n: {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_host_mesh() -> Mesh:
    """1x1 mesh on the real local device (smoke tests, examples)."""
    return jax.make_mesh((1, 1), ("data", "model"), **_AXIS_KW(2))
