import os
# 512 placeholder devices BEFORE any jax import (jax locks device count
# on first init). float-normalization-bf16 is disabled because the CPU
# backend legalizes every bf16 dot by converting operands to f32 — a
# CPU-only artifact that doubles the apparent HBM traffic and, worse,
# gets loop-hoisted over scan-over-layers so the whole stacked KV cache
# materializes in f32. TPU executes bf16 dots natively, so disabling
# the pass (we only compile, never run) gives TPU-realistic
# memory/bytes numbers. See EXPERIMENTS.md §Dry-run.
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512"
                           " --xla_disable_hlo_passes=float-normalization-bf16")
"""Multi-pod dry-run (deliverable e).

Lowers + compiles the real step functions — train_step for train
shapes, ``prefill`` for prefill shapes, ``decode_step`` (serve_step)
for decode shapes — on the production mesh with ShapeDtypeStruct
stand-ins (no allocation), then records memory_analysis,
cost_analysis, and the collective schedule for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
      --shape train_4k [--multi-pod] [--rules v2] [--out results.json]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import InputShape, ModelConfig
from repro.core import cost_model as cm
from repro.distributed import context as dctx
from repro.distributed.sharding import (
    AxisRules, logical_to_spec, rules_for, tree_shardings)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import Model, input_specs
from repro.models.params import abstract_params, param_pspecs
from repro.training import AdamWConfig, TrainConfig, make_train_step
from repro.training import optimizer as opt_mod


# ---------------------------------------------------------------------------
# Batch sharding specs
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, shape: InputShape, rules: AxisRules,
                 mesh) -> Dict[str, P]:
    batch_axes = ("batch", "seq")
    if shape.name == "long_500k":
        # batch=1: shard sequence instead (DESIGN.md §5)
        batch_axes = (None, "batch")
    out = {"tokens": logical_to_spec(batch_axes, rules, mesh)}
    if shape.kind == "train":
        out["labels"] = out["tokens"]
    if shape.kind == "decode":
        out = {"tokens": logical_to_spec(("batch", None), rules, mesh)}
    if cfg.arch_type == "audio" and shape.kind in ("train", "prefill"):
        out["frames"] = logical_to_spec(("batch", "seq", None), rules, mesh)
    if cfg.arch_type == "vlm" and shape.kind in ("train", "prefill"):
        out["prefix"] = logical_to_spec(("batch", None, None), rules, mesh)
    return out


def cache_pspecs(model: Model, rules: AxisRules, mesh):
    axes = model.cache_axes()

    def to_spec(a):
        return logical_to_spec(a, rules, mesh)

    return jax.tree_util.tree_map(
        to_spec, axes, is_leaf=lambda x: isinstance(x, tuple))


# ---------------------------------------------------------------------------
# Dry-run one (arch × shape × mesh)
# ---------------------------------------------------------------------------

def _compile_once(cfg: ModelConfig, shape: InputShape, mesh, rules):
    """Lower + compile the step function for (cfg, shape); returns the
    hlo_analysis stats dict."""
    model = Model(cfg)
    with dctx.use_mesh(mesh), dctx.use_rules(rules):
        specs = model.param_specs()
        params_abs = model.abstract_params()
        p_pspecs = param_pspecs(specs, rules, mesh)
        if cfg.quant_policy not in ("bf16", "f16", "f32"):
            from repro.models.params import match_quantized
            p_pspecs = match_quantized(p_pspecs, params_abs)
        p_shardings = tree_shardings(params_abs, p_pspecs, mesh)
        batch_abs = input_specs(cfg, shape.seq_len, shape.global_batch,
                                shape.kind)
        b_pspecs = batch_pspecs(cfg, shape, rules, mesh)
        b_shardings = tree_shardings(batch_abs, b_pspecs, mesh)

        if shape.kind == "train":
            tcfg = TrainConfig(adamw=AdamWConfig())
            step = make_train_step(model, tcfg)
            opt_abs = jax.eval_shape(opt_mod.init_state, params_abs)
            opt_shardings = opt_mod.AdamWState(
                NamedSharding(mesh, P()),
                tree_shardings(opt_abs.mu, p_pspecs, mesh),
                tree_shardings(opt_abs.nu, p_pspecs, mesh))
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, opt_shardings, b_shardings),
                out_shardings=(p_shardings, opt_shardings, None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        elif shape.kind == "prefill":
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch,
                                         shape.seq_len))
            c_pspecs = cache_pspecs(model, rules, mesh)
            c_shardings = tree_shardings(cache_abs, c_pspecs, mesh)
            jitted = jax.jit(
                model.prefill,
                in_shardings=(p_shardings, b_shardings, c_shardings),
                out_shardings=(None, c_shardings),
                donate_argnums=(2,))
            lowered = jitted.lower(params_abs, batch_abs, cache_abs)
        else:  # decode
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch,
                                         shape.seq_len))
            c_pspecs = cache_pspecs(model, rules, mesh)
            c_shardings = tree_shardings(cache_abs, c_pspecs, mesh)
            jitted = jax.jit(
                model.decode_step,
                in_shardings=(p_shardings, b_shardings["tokens"],
                              c_shardings),
                out_shardings=(None, c_shardings),
                donate_argnums=(2,))
            lowered = jitted.lower(params_abs, batch_abs["tokens"],
                                   cache_abs)

        compiled = lowered.compile()
    return hlo_analysis.analyze_compiled(compiled)


PROBE_TIMEOUT_S = int(os.environ.get("REPRO_PROBE_TIMEOUT", "420"))


def _compile_probe_subprocess(cfg: ModelConfig, shape: InputShape,
                              rules) -> Dict[str, float]:
    """Run one calibration probe in a subprocess with a hard timeout.

    Certain probe configs (unrolled MQA attention over a sharded 32k
    sequence) hit a pathological SPMD partitioner corner and compile for
    >30 min; a subprocess lets us bound that and fall back to the
    analytic graph estimate instead of hanging the sweep.
    """
    import subprocess
    overrides = {f.name: getattr(cfg, f.name)
                 for f in dataclasses.fields(cfg)}
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = ('--xla_force_host_platform_device_count=512"
        " --xla_disable_hlo_passes=float-normalization-bf16')\n"
        "import json, dataclasses\n"
        "from repro.configs.base import ModelConfig, INPUT_SHAPES\n"
        "from repro.launch.dryrun import _compile_once\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "from repro.distributed.sharding import rules_for\n"
        f"cfg = ModelConfig(**json.loads({json.dumps(overrides)!r}))\n"
        f"shape = INPUT_SHAPES[{shape.name!r}]\n"
        "mesh = make_production_mesh()\n"
        f"stats = _compile_once(cfg, shape, mesh, rules_for({rules.name!r}))\n"
        "print('STATS::' + json.dumps({k: stats[k] for k in "
        "('hlo_flops', 'hlo_bytes', 'collective_bytes')}))\n"
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(sys.path)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env,
                          timeout=PROBE_TIMEOUT_S)
    for line in proc.stdout.splitlines():
        if line.startswith("STATS::"):
            return json.loads(line[len("STATS::"):])
    raise RuntimeError(f"probe failed: {proc.stderr[-500:]}")


def _analytic_fallback(cfg: ModelConfig, shape: InputShape,
                       chips: int) -> Dict[str, float]:
    """Graph-model estimate used when calibration probes time out."""
    from repro.core.graph import build_decoder_graph
    seq = 1 if shape.kind == "decode" else shape.seq_len
    kv = shape.seq_len if shape.kind == "decode" else 0
    g = build_decoder_graph(cfg, seq=seq, kv_len=kv,
                            batch=shape.global_batch, fused=True)
    mult = 3.0 if shape.kind == "train" else 1.0
    if shape.kind == "train" and cfg.remat:
        mult = 4.0
    return {"hlo_flops": g.total_flops * mult / chips,
            "hlo_bytes": g.total_bytes * mult / chips,
            "collective_bytes": float("nan"),
            "calibration_fallback": "analytic-graph-model"}


def _calibrated_cost(cfg: ModelConfig, shape: InputShape, mesh, rules
                     ) -> Dict[str, float]:
    """True per-step flops/bytes/collective-bytes.

    XLA cost_analysis counts a while-loop body ONCE, so a scanned
    L-layer stack under-reports by ~L×. We compile small unrolled
    variants (layer stack as a python loop, inner scans unrolled) and
    extrapolate linearly in the layer count; for the hybrid 1:2 pattern
    we probe three depths to price the rglru and attention layers
    separately. attn_block is widened to keep the unrolled HLO small —
    block-granularity mask waste shifts flops by only a few percent.
    """
    # remat=False in the probes: the remat backward under the SPMD
    # partitioner takes 10+ minutes to compile; instead the per-layer
    # FLOP delta is corrected analytically — full per-layer remat adds
    # one forward recompute, i.e. x4/3 over the fwd+bwd cost.
    probe = dict(unroll_scans=True, attn_block=2048, remat=False)
    flop_factor = (4.0 / 3.0 if shape.kind == "train" and cfg.remat
                   else 1.0)

    def cost_at(n_layers: int) -> Dict[str, float]:
        over = dict(probe, num_layers=n_layers)
        if cfg.is_encoder_decoder:
            over["num_encoder_layers"] = n_layers
        c = dataclasses.replace(cfg, **over)
        return _compile_probe_subprocess(c, shape, rules)

    def corrected(k: str, base: float, per_layer_total: float) -> float:
        if k == "hlo_flops":
            return base + flop_factor * per_layer_total
        return base + per_layer_total

    keys = ("hlo_flops", "hlo_bytes", "collective_bytes")
    if cfg.arch_type == "hybrid":
        f1, f2, f3 = cost_at(1), cost_at(2), cost_at(3)
        pattern = cfg.layer_pattern()
        n_rg = sum(k == "rglru" for k in pattern)
        n_at = len(pattern) - n_rg
        out = {}
        for k in keys:
            rg = f2[k] - f1[k]
            at = f3[k] - f2[k]
            base = f1[k] - rg
            out[k] = corrected(k, base, n_rg * rg + n_at * at)
        return out
    f1, f2 = cost_at(1), cost_at(2)
    out = {}
    for k in keys:
        b = f2[k] - f1[k]
        a = f1[k] - b
        out[k] = corrected(k, a, b * cfg.num_layers)
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            rules_version: str = "v2",
            overrides: Optional[Dict] = None,
            calibrate: bool = True,
            verbose: bool = True) -> Dict:
    cfg = get_config(arch, **(overrides or {}))
    from repro.configs.base import SCHEDULER_VERSIONS
    if rules_version in SCHEDULER_VERSIONS:
        cfg = dataclasses.replace(cfg, scheduler_version=rules_version)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(rules_version)
    chips = mesh.size
    t0 = time.time()

    # 1. compile the FULL production config (scan-over-layers): this is
    #    the lower/compile proof + the memory analysis.
    stats = _compile_once(cfg, shape, mesh, rules)
    t_compile = time.time() - t0

    # 2. calibrated per-step cost (see _calibrated_cost docstring).
    raw = {k: stats[k] for k in ("hlo_flops", "hlo_bytes",
                                 "collective_bytes")}
    if calibrate:
        try:
            cal = _calibrated_cost(cfg, shape, mesh, rules)
            stats.update(cal)
            stats["raw_scan_counts"] = raw
        except Exception as e:  # noqa: BLE001
            stats["calibration_error"] = f"{type(e).__name__}: {e}"
            fb = _analytic_fallback(cfg, shape, mesh.size)
            # keep the (undercounted) scan-measured collectives — the
            # analytic graph has no collective model
            fb["collective_bytes"] = raw["collective_bytes"]
            stats.update(fb)
            stats["raw_scan_counts"] = raw

    n_tokens = (shape.global_batch * shape.seq_len
                if shape.kind == "train" else
                shape.global_batch * (1 if shape.kind == "decode"
                                      else shape.seq_len))
    n_params = cfg.param_count()
    n_active = cfg.param_count(active_only=True)
    # MODEL_FLOPS: 6·N·D for training (fwd+bwd), 2·N·D for inference
    mf = (6.0 if shape.kind == "train" else 2.0) * n_active * n_tokens
    terms = cm.roofline(stats["hlo_flops"], stats["hlo_bytes"],
                        stats["collective_bytes"], chips)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "rules": rules_version,
        "chips": chips,
        "kind": shape.kind,
        "ok": True,
        "compile_s": round(t_compile, 1),
        "total_s": round(time.time() - t0, 1),
        "params": n_params,
        "active_params": n_active,
        "model_flops_per_step_global": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flop_ratio": (mf / chips) / stats["hlo_flops"]
            if stats["hlo_flops"] else 0.0,
        **stats,
        "roofline": terms.as_dict(),
    }
    if verbose:
        print(json.dumps({k: v for k, v in result.items()
                          if k != "collectives_by_kind"}, indent=1,
                         default=str))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--rules", default="v2")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the cost-calibration compiles (multi-pod "
                         "runs only need the compile proof; the roofline "
                         "table is single-pod)")
    args = ap.parse_args()

    results = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]
    # resume: skip combos already in the incremental JSONL
    jsonl = (args.out + "l") if args.out else None
    done = set()
    if jsonl and os.path.exists(jsonl):
        with open(jsonl) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"]))
                    results.append(r)
    for arch, shape in combos:
        if (arch, shape) in done:
            continue
        try:
            r = run_one(arch, shape, multi_pod=args.multi_pod,
                        rules_version=args.rules,
                        calibrate=not args.no_calibrate)
        except Exception as e:  # noqa: BLE001 — report, keep going
            r = {"arch": arch, "shape": shape, "ok": False,
                 "error": f"{type(e).__name__}: {e}"}
            print(f"FAIL {arch} x {shape}: {e}", file=sys.stderr)
        results.append(r)
        if jsonl:
            with open(jsonl, "a") as f:
                f.write(json.dumps(r, default=str) + "\n")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_ok = sum(1 for r in results if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} combos compiled OK")
    if n_ok < len(results):
        sys.exit(1)


if __name__ == "__main__":
    main()
