"""Distributed training launcher.

On the container this runs a reduced model on the 1x1 host mesh; on a
real pod the same code path takes ``--mesh 16x16`` (or 2x16x16 with the
pod axis) — the mesh and sharding rules are the only difference, which
is the point of the logical-axis system.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --reduced --steps 30
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.distributed import context as dctx
from repro.distributed.sharding import rules_for, tree_shardings
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Model
from repro.models.params import param_pspecs
from repro.training import (AdamWConfig, DataConfig, TrainConfig, batches,
                            checkpoint, init_state, make_train_step)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--rules", default="v2")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "16x16", "2x16x16"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "2x16x16")
    rules = rules_for(args.rules)
    model = Model(cfg)

    with dctx.use_mesh(mesh), dctx.use_rules(rules):
        params = model.init(jax.random.PRNGKey(0))
        shardings = tree_shardings(
            model.abstract_params(),
            param_pspecs(model.param_specs(), rules, mesh), mesh)
        params = jax.device_put(params, shardings)
        opt = init_state(params)

        tcfg = TrainConfig(
            adamw=AdamWConfig(lr=1e-3, warmup_steps=10,
                              total_steps=args.steps),
            microbatches=args.microbatches)
        step = jax.jit(make_train_step(model, tcfg),
                       donate_argnums=(0, 1))
        data = batches(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq_len,
                                  global_batch=args.batch, kind="lm"))
        t0 = time.time()
        for i in range(args.steps):
            b = next(data)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.arch_type == "audio":
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_seq_len, cfg.d_model),
                    jnp.bfloat16)
            if cfg.arch_type == "vlm":
                batch["prefix"] = jnp.zeros(
                    (args.batch, cfg.num_prefix_embeddings, cfg.d_model),
                    jnp.bfloat16)
            params, opt, m = step(params, opt, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(m['loss']):.4f} "
                      f"({(i + 1) * args.batch * args.seq_len / (time.time() - t0):.0f} tok/s)")
        if args.ckpt:
            checkpoint.save(args.ckpt, {"params": params})
            print("saved", args.ckpt)


if __name__ == "__main__":
    main()
