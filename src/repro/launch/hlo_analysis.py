"""Extract roofline inputs from a compiled executable.

``cost_analysis()`` gives per-device HLO FLOPs and bytes accessed.
Collective bytes are NOT in cost_analysis — we parse the optimized HLO
text and sum the result-operand sizes of every collective op
(all-gather, all-reduce, reduce-scatter, all-to-all,
collective-permute), per the brief.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

# e.g.  %x = bf16[16,512,128]{2,1,0} all-gather(...)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float]]:
    """Total + per-op-kind bytes moved by collectives (result sizes)."""
    per: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        size = sum(_shape_bytes(d, dims)
                   for d, dims in _SHAPE_RE.findall(shapes_str))
        if size:
            per[kind] = per.get(kind, 0.0) + size
    return sum(per.values()), per


def collective_counts(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            counts[m.group(2)] = counts.get(m.group(2), 0) + 1
    return counts


def analyze_compiled(compiled) -> Dict[str, float]:
    """Pull flops / bytes / collective bytes / memory from a compiled
    executable."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll, per = collective_bytes(text)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "peak_bytes": float(ma.temp_size_in_bytes
                                + ma.argument_size_in_bytes),
        }
    except Exception:
        pass
    return {
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "collective_bytes": coll,
        "collectives_by_kind": per,
        **mem,
    }
