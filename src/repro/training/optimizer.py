"""AdamW + cosine schedule + global-norm clipping, pure JAX pytrees.

No optax dependency — the optimizer state is a plain pytree so it
shards with the same logical axes as the parameters (FSDP over
``data``; see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array     # ()
    mu: Any             # pytree like params (f32)
    nu: Any


def init_state(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree_util.tree_map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)))


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig
                  ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step)
        vhat = v / (1 - b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
