from repro.training.optimizer import (
    AdamWConfig, AdamWState, init_state, apply_updates, schedule,
    global_norm,
)
from repro.training.trainer import TrainConfig, make_train_step
from repro.training.data import DataConfig, batches
from repro.training import checkpoint

__all__ = [
    "AdamWConfig", "AdamWState", "init_state", "apply_updates",
    "schedule", "global_norm", "TrainConfig", "make_train_step",
    "DataConfig", "batches", "checkpoint",
]
