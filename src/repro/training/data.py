"""Synthetic data pipeline: deterministic, shardable, no downloads.

Two generators:

- ``lm_stream`` — a Zipf-distributed token stream with short-range
  structure (bigram templates), enough signal that a ~100M model's loss
  visibly drops within a few hundred steps (examples/train_small.py).
- ``copy_task`` — fully learnable toy task for convergence tests.

Batches are plain dicts matching ``Model``'s batch contract; the
launcher shards them via NamedSharding on ("pod","data").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lm"     # "lm" | "copy"


def _zipf_table(vocab: int, rng: np.random.Generator, n: int = 4096):
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    return rng.choice(vocab, size=n, p=probs)


def lm_batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Zipf unigrams + deterministic bigram successor structure."""
    rng = np.random.default_rng(cfg.seed)
    table = _zipf_table(cfg.vocab_size, rng)
    # fixed successor map: half the time the next token is f(prev)
    succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size)
    while True:
        B, S = cfg.global_batch, cfg.seq_len
        draws = table[rng.integers(0, len(table), size=(B, S))]
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = draws[:, 0]
        follow = rng.random((B, S)) < 0.5
        for t in range(1, S):
            toks[:, t] = np.where(follow[:, t], succ[toks[:, t - 1]],
                                  draws[:, t])
        labels = np.concatenate([toks[:, 1:],
                                 np.zeros((B, 1), np.int32)], axis=1)
        yield {"tokens": toks, "labels": labels}


def copy_batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """tokens = [pattern, pattern]: the second half is predictable."""
    rng = np.random.default_rng(cfg.seed)
    half = cfg.seq_len // 2
    while True:
        pat = rng.integers(1, cfg.vocab_size,
                           size=(cfg.global_batch, half)).astype(np.int32)
        toks = np.concatenate([pat, pat], axis=1)
        labels = np.concatenate([toks[:, 1:],
                                 np.zeros((cfg.global_batch, 1), np.int32)],
                                axis=1)
        yield {"tokens": toks, "labels": labels}


def batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    return lm_batches(cfg) if cfg.kind == "lm" else copy_batches(cfg)
