"""Msgpack pytree checkpointing (no orbax in this container).

Arrays are stored as raw bytes + dtype/shape; the tree structure is
reconstructed from nested msgpack maps. QuantizedTensor nodes serialize
via their pytree children plus static aux data.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.quant.quantize import QuantizedTensor

_ARR = "__arr__"
_QT = "__quant__"


def _encode(obj):
    if isinstance(obj, QuantizedTensor):
        return {_QT: True,
                "data": _encode(obj.data), "scales": _encode(obj.scales),
                "fmt": obj.fmt, "shape": list(obj.shape),
                "group": obj.group}
    if isinstance(obj, (jax.Array, np.ndarray)):
        arr = np.asarray(obj)
        if arr.dtype == jnp.bfloat16:
            return {_ARR: True, "dtype": "bfloat16",
                    "shape": list(arr.shape),
                    "bytes": arr.view(np.uint16).tobytes()}
        return {_ARR: True, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "bytes": arr.tobytes()}
    if isinstance(obj, dict):
        return {k: _encode(v) for k, v in obj.items()}
    if hasattr(obj, "_asdict"):   # NamedTuple — check BEFORE tuple
        return {"__nt__": type(obj).__name__,
                **{k: _encode(v) for k, v in obj._asdict().items()}}
    if isinstance(obj, (list, tuple)):
        return {"__list__": [_encode(v) for v in obj],
                "__tuple__": isinstance(obj, tuple)}
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    raise TypeError(f"cannot checkpoint {type(obj)}")


def _decode(obj):
    if isinstance(obj, dict):
        if obj.get(_ARR):
            if obj["dtype"] == "bfloat16":
                arr = np.frombuffer(obj["bytes"], np.uint16).reshape(
                    obj["shape"])
                return jnp.asarray(arr).view(jnp.bfloat16)
            arr = np.frombuffer(
                obj["bytes"], np.dtype(obj["dtype"])).reshape(obj["shape"])
            return jnp.asarray(arr)
        if obj.get(_QT):
            # "shape" in older checkpoints is ignored: the logical shape
            # is derived from the decoded data array (authoritative)
            return QuantizedTensor(
                _decode(obj["data"]), _decode(obj["scales"]), obj["fmt"],
                obj["group"])
        if "__list__" in obj:
            items = [_decode(v) for v in obj["__list__"]]
            return tuple(items) if obj.get("__tuple__") else items
        if "__nt__" in obj:
            from repro.training.optimizer import AdamWState
            kinds = {"AdamWState": AdamWState}
            cls = kinds[obj["__nt__"]]
            return cls(**{k: _decode(v) for k, v in obj.items()
                          if k != "__nt__"})
        return {k: _decode(v) for k, v in obj.items()}
    return obj


def save(path: str, tree: Any) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(_encode(tree), use_bin_type=True))


def restore(path: str) -> Any:
    with open(path, "rb") as f:
        return _decode(msgpack.unpackb(f.read(), raw=False,
                                       strict_map_key=False))
