"""Training step assembly: loss → grads → AdamW, with optional
microbatch gradient accumulation (a §Perf lever: trades activation
memory against step latency).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import Model
from repro.training import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = opt.AdamWConfig()
    microbatches: int = 1     # grad accumulation steps per train_step


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics). jit/pjit is applied by the caller with shardings."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def single(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = opt.apply_updates(
            params, grads, opt_state, tcfg.adamw)
        metrics["loss"] = loss
        return params, opt_state, metrics

    if tcfg.microbatches == 1:
        return single

    n = tcfg.microbatches

    def accumulated(params, opt_state, batch):
        def reshape(x):
            b = x.shape[0]
            return x.reshape(n, b // n, *x.shape[1:])
        micro = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            loss_sum, grads = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            grads = jax.tree_util.tree_map(jnp.add, grads, g)
            return (loss_sum + l, grads), None

        zero_grads = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zero_grads), micro)
        grads = jax.tree_util.tree_map(lambda g: g / n, grads)
        params, opt_state, metrics = opt.apply_updates(
            params, grads, opt_state, tcfg.adamw)
        metrics["loss"] = loss_sum / n
        return params, opt_state, metrics

    return accumulated
