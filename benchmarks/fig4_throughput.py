"""Fig 4 reproduction: throughput across models × precision × backend.

The paper's measurement is tokens/s on an iPhone 15 Pro; this container
has no A17, so the numbers come from the calibrated analytic model
(core/cost_model + core/scheduler) over the same grid: six models,
{F16, Q8, Q4}, {GPU, 1-6 CPU threads}. EXPERIMENTS.md compares the
model's predictions against the paper's measured headline points.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs.paper_models import PAPER_MODELS
from repro.core import backend_throughput

HEADLINES = {
    # (model, backend, threads, fmt) -> paper-measured tk/s
    ("llama3.2-1b", "cpu", 2, "f16"): 17.0,
    ("llama3.2-1b", "gpu", 0, "f16"): 12.8,
}


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for name, cfg in PAPER_MODELS.items():
        mem_gb = cfg.param_count() * 2 / 1e9
        for fmt in ("f16", "q8_0", "q4_0"):
            if mem_gb > 6.5 and fmt in ("f16", "q8_0"):
                # paper §5.1: 7B/8B F16+Q8 exceed the 8GB device (mmap
                # failure) — reproduce the missing data points
                rows.append((f"fig4/{name}/{fmt}/oom", 0.0, "mmap-fail"))
                continue
            t0 = time.perf_counter()
            gpu = backend_throughput(cfg, "gpu", weight_format=fmt)
            cpu_by_t = {t: backend_throughput(cfg, "cpu", threads=t,
                                              weight_format=fmt)
                        for t in range(1, 7)}
            us = (time.perf_counter() - t0) * 1e6
            best_t = max(cpu_by_t, key=cpu_by_t.get)
            derived = (f"gpu={gpu:.1f}tk/s "
                       f"cpu_best={cpu_by_t[best_t]:.1f}tk/s@{best_t}t "
                       f"ratio={cpu_by_t[best_t] / gpu:.2f}")
            rows.append((f"fig4/{name}/{fmt}", us, derived))
    # headline check rows
    for (name, backend, th, fmt), want in HEADLINES.items():
        got = backend_throughput(PAPER_MODELS[name], backend,
                                 threads=max(th, 1), weight_format=fmt)
        rows.append((f"fig4/headline/{name}/{backend}{th}t", 0.0,
                     f"pred={got:.1f} paper={want:.1f} "
                     f"err={abs(got - want) / want * 100:.0f}%"))
    return rows
