"""Figs 8-10 reproduction: the execution-version ladder V0-V3.

Paper (llama3.2-1B F16): serial 11.5 → graph-parallel 13 →
graph+tensor 15 → heterogeneous CPU+GPU 6 tk/s. On TPU the same
structure appears as sharding rulesets v0-v3 (DESIGN.md §2); the
mobile ladder here is the calibrated model, the TPU analogue is in
roofline_table.py (v3's collective term explosion).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs.paper_models import LLAMA32_1B
from repro.core import simulate_version

PAPER = {"v0": 11.5, "v1": 13.0, "v2": 15.0, "v3": 6.0}


def run() -> List[Tuple[str, float, str]]:
    rows = []
    for v, want in PAPER.items():
        t0 = time.perf_counter()
        r = simulate_version(LLAMA32_1B, v, threads=4, kv_len=64)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"fig8-10/{v}", us,
            f"pred={r.tokens_per_s:.1f}tk/s paper={want:.1f} "
            f"({r.detail})"))
    return rows
