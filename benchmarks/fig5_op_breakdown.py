"""Fig 5 reproduction: op-class time shares, prefill vs decode.

Paper (llama3.2-1B F16, A17 CPU): MUL_MAT = 87.6% prefill / 76.2%
decode. Derived column reports our model's shares for the same setup.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs.paper_models import LLAMA32_1B
from repro.core import profile_phases

PAPER = {"prefill": 0.876, "decode": 0.762}


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    profs = profile_phases(LLAMA32_1B, threads=2)
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for phase, prof in profs.items():
        top = sorted(prof.by_op.items(), key=lambda kv: -kv[1])[:4]
        shares = " ".join(f"{k}={v / prof.total_s * 100:.1f}%"
                          for k, v in top)
        rows.append((
            f"fig5/{phase}", us / 2,
            f"mul_mat={prof.mul_mat_share * 100:.1f}% "
            f"(paper={PAPER[phase] * 100:.1f}%) | {shares}"))
    return rows
