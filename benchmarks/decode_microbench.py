"""Real wall-clock microbenchmarks (this machine, reduced models).

Unlike the fig4/5/6 analytic reproductions, these rows *execute*: a
reduced llama-family model decodes real tokens on the container CPU,
with and without the paper's fusion technique and across precisions —
demonstrating the technique end-to-end on live hardware (the container
CPU stands in for the paper's mobile CPU).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import Model


def _bench_decode(cfg, steps: int = 20) -> float:
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 4
    cache = model.init_cache(B, 128)
    tokens = jnp.zeros((B, 8), jnp.int32)
    _, cache = jax.jit(model.prefill)(params, {"tokens": tokens}, cache)
    step = jax.jit(model.decode_step)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = step(params, tok, cache)   # compile
    jax.block_until_ready(logits)
    t0 = time.perf_counter()
    for _ in range(steps):
        logits, cache = step(params, tok, cache)
    jax.block_until_ready(logits)
    return (time.perf_counter() - t0) / steps * 1e6


def run() -> List[Tuple[str, float, str]]:
    base = reduced(get_config("deepseek-7b"),
                   num_layers=4, d_model=256, d_ff=512)
    rows = []
    results = {}
    for label, over in (
            ("fused-bf16", dict()),
            ("unfused-bf16", dict(scheduler_version="v0")),
            ("fused-q8", dict(quant_policy="q8_0")),
            ("fused-q4", dict(quant_policy="q4_0")),
    ):
        cfg = dataclasses.replace(base, **over)
        us = _bench_decode(cfg)
        results[label] = us
        rows.append((f"microbench/decode/{label}", us,
                     f"{4 / (us / 1e6):.0f} tok/s (batch 4)"))
    speed = results["unfused-bf16"] / results["fused-bf16"]
    rows.append(("microbench/fusion_speedup", 0.0,
                 f"fused vs unfused decode: {speed:.2f}x"))
    return rows
