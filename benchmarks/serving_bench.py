"""End-to-end serving benchmark: the ServingEngine decoding batched
requests on a reduced model (live execution).

Eight sweeps (``--sweep
megastep|mixed|precision|kv|kernels|async|paging|overload|all``):

1. **Megastep sweep** — ``K ∈ {1, 4, 8, 16}``, all requests queued
   upfront (stall admission, the PR-1 configuration): K=1 reproduces
   the per-token-dispatch configuration the paper's §5 measures losing
   on the Apple GPU; larger K amortizes the host dispatch over one
   fused ``lax.scan``.
2. **Mixed-workload sweep** — a seeded Poisson-ish arrival trace of
   short-prompt requests lands *while the batch decodes*, replayed
   identically against stall-prefill admission (each arrival wave
   pays a batched-prefill dispatch that stalls every decoding slot)
   and chunked-prefill admission (prompts ride inside the megastep
   scan; zero extra dispatches). This is the regime where the
   sustained-load studies (arXiv:2410.03613) put the on-device
   collapse — and where the dispatch-overhead lesson says chunked
   admission must win decode-phase tokens/s.
3. **Precision sweep** — {bf16, q8_0, q4_0} × K ∈ {1, 8} serving
   decode, the paper's §5.3 quantization table reproduced through the
   megastep engine. The JSON's ``precision`` section is the live
   counterpart of the paper's F16/Q8_0/Q4_0 throughput columns: per
   (format, K) decode-phase tok/s, the q4_0/bf16 ratio at K=8, the
   measured weight-bytes ratio (paper fn.1: Q4_0 = 4.5 bits/weight),
   and the analytic prediction from
   ``core.scheduler.simulate_precision`` (the memory-roofline §5.3
   model) next to the measurement — when the backend's dequant path
   inverts the predicted ordering, that gap is the recorded finding
   (see ROADMAP.md).
4. **KV-precision sweep** — {bf16, q8_0, q4_0} *cache* × K ∈ {1, 8}
   at a long-context operating point: the cache is the decode stream
   that grows with context/batch, so this is where the paper's
   CPU-vs-GPU crossover math lives at long context. The JSON's
   ``kv_precision`` section reports decode tok/s per (format, K), the
   measured cache-bytes ratio (must come out ≈ bits/16: int8 payload +
   groupwise scales), and ``simulate_kv_precision``'s prediction at
   toy and paper-scale context.
5. **Kernel-backend sweep** — {q8_0, q4_0} weights+cache ×
   {xla, pallas} through the engine: greedy token-identity across
   backends (the fused-dequant kernel contract) plus the analytic
   TPU-v5e planner flip (xla prices the materialized q4 unpack and
   picks q8_0; the fused pallas backend hands the win back to q4_0).
   Emitted as the JSON's ``kernel_backend`` section.

6. **Async-overlap sweep** — ``pipeline_depth ∈ {1, 2, 4}`` on the
   same engine (the knob is pure host orchestration; the compiled
   megastep is shared) at **K=1**, the paper's per-token-dispatch
   regime: with one decode token per dispatch the host's per-megastep
   work — dispatch-call overhead, draining the packed ``(tokens,
   emitted, pos)`` block, staging the next admission — is comparable
   to the device step, so hiding it behind in-flight megasteps is
   exactly the §5 launch-overhead story attacked from the other side
   (pipelining instead of amortization). The measured gap is
   ``(decode_wall - drain_wait) / megasteps``: host-side work that
   extends the serving period beyond the device wait. It shrinks at
   depth > 1 because part of the dispatch/drain runs while the device
   executes the previous in-flight megastep. Two measured caveats are
   recorded rather than hidden: (a) carry *donation* serializes the
   dispatch chain on this backend (donating a buffer that is itself a
   pending computation's output blocks the call until it
   materializes), so the sweep runs ``donate_carries=False`` — the
   donation-vs-overlap tradeoff is real and the section says so; (b)
   at K >= 2 the device step dwarfs the host gap and the stale slot
   view's wasted trailing substeps eat the overlap win — amortization
   and pipelining attack the same gap, and once K has amortized it
   there is nothing left to hide. Greedy token-identity across depths
   is asserted (pipelining must move time, never tokens), and
   ``simulate_async_overlap`` provides the analytic prediction.
   Emitted as the JSON's ``async_overlap`` section.

7. **Paging sweep** — dense per-slot cache vs the paged pool
   (``page_size ∈ {8, 16, 32}``) through the engine: greedy
   token-identity (paging moves bytes, never tokens), decode tok/s
   (the gather-indirection tax, a pure cost at prefix hit rate 0),
   and the tentpole claim — *cache bytes scale with live tokens*:
   the dense engine preallocates ``slots x max_len`` rows while the
   paged pool's peak in-use blocks track the workload's live token
   count across increasing loads. A prefix-cache leg serves a
   shared-system-prompt workload (the Xiao et al. mobile traffic
   shape) and records hit/hit-token counters plus the admission
   substeps the copy-on-write mapping saves.
   ``simulate_paging`` provides the analytic twin. Emitted as the
   JSON's ``paging`` section.

8. **Overload sweep** — seeded Poisson arrivals at {1, 2, 3}x the
   engine's *measured* capacity, replayed tick-identically against
   two admission policies on the same compiled engine: **bounded**
   (``max_queue = 2 x slots`` + per-request deadlines → typed sheds
   at submit, EDF ordering, pool-starved preemption on a deliberately
   undersized block pool) and the **unbounded baseline** (everything
   admitted FIFO, deadlines tracked host-side only). Past capacity
   the unbounded backlog grows without bound, so late arrivals blow
   through their deadlines and goodput (tokens of deadline-hitting
   requests per second) decays — while the bounded policy sheds the
   excess at admission and holds goodput ~flat. Records shed rate,
   preemption rate, deadline-hit rate, goodput tok/s, and p95
   latency per (multiple, policy); ``engine.audit()`` asserts the
   block-pool invariants after every storm.
   ``scheduler.simulate_overload`` is the analytic twin — the JSON
   records whether it predicts the measured shed-rate ordering.
   Emitted as the JSON's ``overload`` section.

Emits ``BENCH_serving.json`` at the repo root (tok/s per K, the K8/K1
speedup, the chunked/stall mixed-workload ratio, the precision table +
greedy equivalence bits) so future PRs have a perf trajectory to
regress against. Sections are merged into an existing file, so running
one sweep never clobbers another's numbers.

Methodology (standing note, enforced since PR 9): every timed decode
region auto-extends its pass count until it spans at least
``MIN_TIMED_S`` (0.15 s) — shorter regions measured 0.63-1.49x
run-to-run swings on this shared container — and each section records
the achieved duration (``decode_wall_s``) plus the pass count it took
(``timed_passes``).
"""
from __future__ import annotations

import argparse
import collections
import json
import pathlib
import time
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.quant.quantize import QuantizedTensor
from repro.serving import (Request, SamplingConfig, ServingEngine,
                           SubmitReject)

KS = (1, 4, 8, 16)
N_REQUESTS = 32
MAX_NEW = 48
SLOTS = 4
REPS = 3

# bench methodology floor (PR-3 standing note, enforced here): timed
# decode regions below this duration swung 0.63-1.49x run-to-run on
# this shared container, so any region that comes up short auto-extends
# its pass count until it clears the bar (see _timed_region)
MIN_TIMED_S = 0.15

# precision sweep: the §5.3 ladder through the serving engine; K=1
# isolates per-dispatch cost per format, K=8 is the amortized serving
# operating point where the memory-roofline win should show. Workload
# matches the megastep sweep (the timed decode region must be long
# enough to dominate scheduler noise on a shared container).
PRECISIONS = ("bf16", "q8_0", "q4_0")
PREC_KS = (1, 8)
PREC_REQUESTS = 32
PREC_MAX_NEW = 48
PREC_REPS = 3

# kv-cache precision sweep (paper §5.3 applied to the *other* decode
# stream): long-context operating point — prompts 40-56 tokens into a
# 192-position cache, 48 generated tokens — so the per-step cache read
# is non-negligible next to the weight stream on this toy model. K=1
# isolates the dispatch floor per cache format; K=8 is the amortized
# serving point where a bandwidth win can show. Sized so the timed
# decode region stays ≥0.15 s (PR-3 methodology note: shorter regions
# swung 0.63-1.49x run-to-run on this shared container).
KV_PRECISIONS = ("bf16", "q8_0", "q4_0")
KV_KS = (1, 8)
KV_REQUESTS = 32
KV_MAX_NEW = 48
KV_MAX_LEN = 192
KV_PROMPT_RANGE = (40, 57)
KV_REPS = 3

# kernel-backend sweep: quantized weights + quantized cache served
# through the fused Pallas dequant kernels (quant_matmul +
# decode_attention_quant) vs the materialized-unpack XLA fallback.
# On this CPU container Pallas runs in interpret mode, so the *wall
# numbers are not the TPU story* — the recorded claims are (a) greedy
# token-identity across backends (the engine contract the kernels were
# built against) and (b) the analytic q4-vs-q8 ordering flip on
# TPU-class bandwidth, which only the fused backend produces.
KB_FORMATS = ("q8_0", "q4_0")
KB_BACKENDS = ("xla", "pallas")
KB_K = 8
KB_REQUESTS = 16
KB_MAX_NEW = 32
KB_MAX_LEN = 128
KB_PROMPT_RANGE = (24, 41)
KB_REPS = 2

# async-overlap sweep: serial vs pipelined dispatch/drain loop at the
# paper's K=1 per-token-dispatch operating point (at larger K the
# megastep has already amortized the host gap this sweep hides — see
# the module docstring). Chunked admission (the pipelined loop's
# steady state: admissions staged during megastep N ride into N+1's
# slot view); donation off because chained-carry donation serializes
# dispatch on this backend. One engine serves every depth — the knob
# is host-side orchestration over the same compiled executable — so
# the comparison can't be confounded by separate jit caches. Sized so
# the timed decode region stays ≥0.15 s (PR-3 methodology note).
# 16 long-generation requests = 4 retirement waves on 4 slots: the
# stale-view tax (a retiring slot idles up to depth-1 extra substeps
# before the host sees it) stays small next to the steady-state loop
ASYNC_DEPTHS = (1, 2, 4)
ASYNC_REQUESTS = 16
ASYNC_MAX_NEW = 96
ASYNC_K = 1
ASYNC_REPS = 5

# paging sweep: dense vs paged cache through the engine. Loads grow so
# the peak live token count grows while the dense prealloc stays fixed
# — the "cache bytes scale with live tokens" claim measured, not
# asserted. The prefix leg's workload is Xiao et al.'s mobile shape:
# every request opens with the same system prefix, unique tail after.
PAGE_SIZES = (8, 16, 32)
PAGING_MAX_LEN = 128
PAGING_MAX_NEW = 32
PAGING_PROMPT_RANGE = (20, 37)
PAGING_LOADS = (2, 4, 12)      # requests per load point (4 slots)
PAGING_REPS = 2
PAGING_PREFIX_LEN = 24         # shared system-prompt head
PAGING_PREFIX_REQUESTS = 12

# mixed workload: admission-heavy traffic (short prompts, short
# generations, ~2 arrivals per megastep → every megastep boundary has
# admissions pending, but riding stays within slot capacity) — the
# stall-vs-chunked comparison's operating point
MIX_REQUESTS = 96
MIX_MAX_NEW = 6
MIX_K = 8
MIX_REPS = 5

# overload sweep: Poisson arrivals past measured capacity. The block
# pool is deliberately undersized (12 usable blocks vs a 4-slot x
# 3-4-page worst case) so pool-starved admissions exercise EDF
# preemption, and per-request deadlines vary so urgent late arrivals
# hold strictly-earlier EDF keys than lax residents (the victim
# eligibility rule). Deadlines are drawn relative to the *measured*
# aggregate service time so the operating point self-calibrates to
# whatever this container runs at: the bounded queue's worst-case wait
# (~queue_bound x service) must straddle the deadline band for the
# policies to separate.
OV_SLOTS = 4
OV_K = 8
OV_MAX_LEN = 64
OV_MAX_NEW = 12
OV_PROMPT_RANGE = (8, 21)
OV_PAGE = 8
OV_BLOCKS = 13                  # 12 usable: < slots x 4-page worst case
OV_QUEUE_BOUND = 2 * OV_SLOTS
OV_MULTIPLES = (1.0, 2.0, 3.0)  # x measured capacity
OV_REQUESTS = 40                # arrivals per replay pass
OV_DEADLINE_RANGE = (6.0, 14.0)  # x measured service_s, per request
OV_SEED = 7


def _requests(n: int = N_REQUESTS, max_new: int = MAX_NEW):
    return [Request(uid=i, prompt=np.arange(5 + i % 8, dtype=np.int32) + 1,
                    max_new_tokens=max_new) for i in range(n)]


def _pass(engine, n: int = N_REQUESTS, max_new: int = MAX_NEW):
    """One full pass over the standard workload. Returns (end-to-end
    wall, decode-phase wall, decode tokens, total tokens, outputs)."""
    reqs = _requests(n, max_new)
    for r in reqs:
        engine.submit(r)
    tokens0 = engine.stats.tokens_generated
    prefills0 = engine.stats.prefills
    decode0 = engine.stats.decode_wall_s
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    tokens = engine.stats.tokens_generated - tokens0
    dec_tokens = tokens - (engine.stats.prefills - prefills0)
    return (dt, engine.stats.decode_wall_s - decode0, dec_tokens,
            tokens, [r.output for r in reqs])


def _timed_region(pass_fn, timed_idx: int = 0, *,
                  min_s: float = MIN_TIMED_S, passes: int = 0,
                  max_passes: int = 64):
    """One timed region built from consecutive passes of ``pass_fn``.

    ``pass_fn`` returns a tuple of numeric wall/token measurements
    with the pass outputs last; the numerics are summed across passes
    and the region keeps extending until the wall at ``timed_idx``
    reaches ``min_s`` (the bench methodology floor — see module
    docstring). ``passes`` > 0 pins a *minimum* pass count (so
    best-of reps compare near-identical workloads), but the ``min_s``
    floor still applies: a rep that comes in faster than the first
    one keeps extending rather than recording an under-floor region.
    Returns ``(*summed_numerics, outputs, n_passes)``.
    """
    totals, outputs, n = None, None, 0
    while (n == 0 or n < passes
           or (totals[timed_idx] < min_s and n < max_passes)):
        res = pass_fn()
        outputs = res[-1]
        nums = res[:-1]
        totals = nums if totals is None else \
            tuple(a + b for a, b in zip(totals, nums))
        n += 1
    return (*totals, outputs, n)


def _mixed_trace(cfg, seed: int = 0):
    """Deterministic Poisson-ish arrival trace: (arrival_tick, Request)
    pairs, arrival measured in engine steps so both admission modes
    replay the identical schedule. Prompt lengths vary across buckets
    so stall admission pays realistically-fragmented dispatches."""
    rng = np.random.default_rng(seed)
    trace = []
    tick = 0
    for i in range(MIX_REQUESTS):
        plen = int(rng.integers(3, 14))
        prompt = rng.integers(1, cfg.vocab_size,
                              size=plen).astype(np.int32)
        trace.append((tick, Request(uid=i, prompt=prompt,
                                    max_new_tokens=MIX_MAX_NEW)))
        tick += int(rng.integers(0, 2))
    return trace


def _run_mixed(engine, cfg, seed: int = 0):
    """Replay the arrival trace. Returns (wall, decode tokens, total
    tokens, dispatches, outputs)."""
    trace = collections.deque(_mixed_trace(cfg, seed))
    n_req = len(trace)
    reqs = [r for _, r in trace]
    mega0 = engine.stats.megasteps
    pf0 = engine.stats.prefill_batches
    tok0 = engine.stats.tokens_generated
    tick = 0
    t0 = time.perf_counter()
    while trace or engine.queue or any(
            r is not None for r in engine.active):
        while trace and trace[0][0] <= tick:
            engine.submit(trace.popleft()[1])
        engine.step()
        tick += 1
    wall = time.perf_counter() - t0
    tokens = engine.stats.tokens_generated - tok0
    dispatches = (engine.stats.megasteps - mega0 +
                  engine.stats.prefill_batches - pf0)
    return wall, tokens - n_req, tokens, dispatches, \
        [r.output for r in reqs]


def _build_model():
    # batch-1-style decode on a small model is the dispatch-bound regime
    # the paper's §5 measures; keep the device step small so the sweep
    # exposes the launch-overhead amortization rather than raw FLOPs
    cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=1,
                  unroll_scans=True)   # 2 layers: unroll beats while-loop
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _param_bytes(params) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total += leaf.quant_nbytes
        else:
            total += leaf.size * leaf.dtype.itemsize
    return total


def _sweep_precision(cfg, model, params, out, rows) -> None:
    """{bf16, q8_0, q4_0} × K ∈ {1, 8} through the megastep engine —
    the paper's §5.3 precision table as a serving measurement."""
    from repro.quant.quantize import quantize_tree
    # quantize once per format; every engine of that format shares the
    # tree (the engine's matching-policy path is a no-op)
    params_by_fmt = {
        fmt: (params if fmt == "bf16"
              else quantize_tree(params, fmt, cfg.quant_group))
        for fmt in PRECISIONS}
    engines = {
        (fmt, k): ServingEngine(model, params_by_fmt[fmt], slots=SLOTS,
                                max_len=64,
                                sampling=SamplingConfig(),  # greedy
                                megastep_k=k, admission="stall",
                                megastep_unroll=True, quant_policy=fmt)
        for fmt in PRECISIONS for k in PREC_KS}
    # best-of per metric independently (same methodology as the
    # megastep sweep: a rep with the best decode phase may have a
    # noisy prefill phase and vice versa)
    best_dt = {key: float("inf") for key in engines}
    best_dec = {key: float("inf") for key in engines}
    tokens, dec_tokens, outputs, n_passes = {}, {}, {}, {}
    for key, eng in engines.items():             # untimed: compilation
        _pass(eng, PREC_REQUESTS, PREC_MAX_NEW)
        eng.reset()
    for _ in range(PREC_REPS):                   # interleave reps so
        for key, eng in engines.items():         # load hits all alike
            dt, dec_dt, dec_tokens[key], tokens[key], outputs[key], \
                n = _timed_region(
                    lambda e=eng: _pass(e, PREC_REQUESTS,
                                        PREC_MAX_NEW),
                    1, passes=n_passes.get(key, 0))
            n_passes[key] = n
            best_dt[key] = min(best_dt[key], dt)
            best_dec[key] = min(best_dec[key], dec_dt)
            eng.reset()

    bf16_bytes = _param_bytes(params)
    formats: Dict[str, Dict] = {}
    for fmt in PRECISIONS:
        per_k = {}
        for k in PREC_KS:
            key = (fmt, k)
            per_k[f"k{k}"] = {
                "decode_tok_s": round(dec_tokens[key] / best_dec[key], 1),
                "tok_s": round(tokens[key] / best_dt[key], 1),
                "decode_wall_s": round(best_dec[key], 4),
                "tokens": tokens[key],
                "timed_passes": n_passes[key],
            }
        qbytes = _param_bytes(params_by_fmt[fmt])
        formats[fmt] = {
            **per_k,
            "weight_bytes": qbytes,
            "weight_bytes_ratio": round(qbytes / bf16_bytes, 3),
            # greedy K-invariance must hold *within* a format (the
            # engine contract); tokens may differ across formats
            "greedy_equiv_k8_k1":
                outputs[(fmt, 1)] == outputs[(fmt, 8)],
        }

    q4 = formats["q4_0"]["k8"]["decode_tok_s"]
    b16 = formats["bf16"]["k8"]["decode_tok_s"]

    # analytic twin: the §5.3 memory-roofline prediction for the same
    # sweep on the paper's 2-thread A17 CPU operating point
    from repro.core import a17_cpu, simulate_precision
    sim = simulate_precision(cfg, a17_cpu(2), kv_len=48,
                             formats=PRECISIONS, ks=PREC_KS)
    analytic = {fmt: {f"k{k}": round(sim[fmt][k].tokens_per_s, 1)
                      for k in PREC_KS} for fmt in PRECISIONS}

    out["precision"] = {
        "requests": PREC_REQUESTS, "max_new": PREC_MAX_NEW,
        "slots": SLOTS, "sampling": "greedy", "admission": "stall",
        "min_timed_s": MIN_TIMED_S,
        "formats": formats,
        "q4_over_bf16_k8_decode": round(q4 / b16, 2),
        "q8_over_bf16_k8_decode": round(
            formats["q8_0"]["k8"]["decode_tok_s"] / b16, 2),
        "analytic_a17_2t": {
            **analytic,
            "q4_over_f16_k8": round(
                analytic["q4_0"]["k8"] / analytic["bf16"]["k8"], 2)},
    }
    rows.append((
        "serving/precision_q4_over_bf16_k8", q4 / b16 * 100,
        f"q4_0 {q4:.0f} vs bf16 {b16:.0f} decode tok/s at K=8 "
        f"(= {q4 / b16:.2f}x; analytic a17-2t predicts "
        f"{out['precision']['analytic_a17_2t']['q4_over_f16_k8']:.2f}x; "
        f"weight bytes ratio "
        f"{formats['q4_0']['weight_bytes_ratio']:.3f})"))


def _kv_requests(cfg, seed: int = 7):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size,
                        size=int(rng.integers(*KV_PROMPT_RANGE))
                    ).astype(np.int32),
                    max_new_tokens=KV_MAX_NEW)
            for i in range(KV_REQUESTS)]


def _kv_pass(engine, cfg):
    """One pass over the long-context workload. Returns (decode wall,
    decode tokens, total tokens, outputs)."""
    reqs = _kv_requests(cfg)
    for r in reqs:
        engine.submit(r)
    tokens0 = engine.stats.tokens_generated
    prefills0 = engine.stats.prefills
    decode0 = engine.stats.decode_wall_s
    engine.run()
    tokens = engine.stats.tokens_generated - tokens0
    dec_tokens = tokens - (engine.stats.prefills - prefills0)
    return (engine.stats.decode_wall_s - decode0, dec_tokens, tokens,
            [r.output for r in reqs])


def _sweep_kv(cfg, model, params, out, rows) -> None:
    """{bf16, q8_0, q4_0} cache × K ∈ {1, 8} through the megastep
    engine at long context — decode tok/s + the measured cache-bytes
    ratio (≈ bits/16 per format: int8 payload + groupwise scales)."""
    engines = {
        (fmt, k): ServingEngine(model, params, slots=SLOTS,
                                max_len=KV_MAX_LEN,
                                sampling=SamplingConfig(),  # greedy
                                megastep_k=k, admission="stall",
                                megastep_unroll=True, kv_quant=fmt)
        for fmt in KV_PRECISIONS for k in KV_KS}
    best_dec = {key: float("inf") for key in engines}
    tokens, dec_tokens, outputs, n_passes = {}, {}, {}, {}
    for key, eng in engines.items():             # untimed: compilation
        _kv_pass(eng, cfg)
        eng.reset()
    for _ in range(KV_REPS):                     # interleave reps so
        for key, eng in engines.items():         # load hits all alike
            dec_dt, dec_tokens[key], tokens[key], outputs[key], n = \
                _timed_region(lambda e=eng: _kv_pass(e, cfg), 0,
                              passes=n_passes.get(key, 0))
            n_passes[key] = n
            best_dec[key] = min(best_dec[key], dec_dt)
            eng.reset()

    bf16_cache = engines[("bf16", 1)].cache_nbytes()
    formats: Dict[str, Dict] = {}
    for fmt in KV_PRECISIONS:
        per_k = {}
        for k in KV_KS:
            key = (fmt, k)
            per_k[f"k{k}"] = {
                "decode_tok_s": round(dec_tokens[key] / best_dec[key], 1),
                "decode_wall_s": round(best_dec[key], 4),
                "tokens": tokens[key],
                "timed_passes": n_passes[key],
            }
        cbytes = engines[(fmt, 1)].cache_nbytes()
        formats[fmt] = {
            **per_k,
            "cache_bytes": cbytes,
            "cache_bytes_ratio": round(cbytes / bf16_cache, 4),
            # greedy K-invariance must hold *within* a cache format
            # (the engine contract); tokens may differ across formats
            # (cache roundtrip drift is legal, reference-pinned in the
            # property suite)
            "greedy_equiv_k8_k1":
                outputs[(fmt, 1)] == outputs[(fmt, 8)],
        }

    b16 = formats["bf16"]["k8"]["decode_tok_s"]
    q8 = formats["q8_0"]["k8"]["decode_tok_s"]
    q4 = formats["q4_0"]["k8"]["decode_tok_s"]

    # analytic twin: the cache-stream prediction at this toy context
    # and at paper-scale long context on the 2-thread A17 point
    from repro.core import a17_cpu, simulate_kv_precision
    sim = simulate_kv_precision(cfg, a17_cpu(2), ks=KV_KS,
                                kv_lens=(KV_MAX_LEN, 32768))
    analytic = {fmt: {f"ctx{kvl}": {
        f"k{k}": round(sim[fmt][kvl][k].tokens_per_s, 2) for k in KV_KS}
        for kvl in (KV_MAX_LEN, 32768)} for fmt in KV_PRECISIONS}

    out["kv_precision"] = {
        "requests": KV_REQUESTS, "max_new": KV_MAX_NEW,
        "max_len": KV_MAX_LEN,
        "prompt_len": f"{KV_PROMPT_RANGE[0]}-{KV_PROMPT_RANGE[1] - 1}",
        "slots": SLOTS, "sampling": "greedy", "admission": "stall",
        "min_timed_s": MIN_TIMED_S,
        "formats": formats,
        "q8_over_bf16_k8_decode": round(q8 / b16, 2),
        "q4_over_bf16_k8_decode": round(q4 / b16, 2),
        "analytic_a17_2t": analytic,
    }
    rows.append((
        "serving/kv_q8_over_bf16_k8", q8 / b16 * 100,
        f"q8_0 cache {q8:.0f} vs bf16 {b16:.0f} decode tok/s at K=8 "
        f"long-context (= {q8 / b16:.2f}x; cache bytes ratio "
        f"{formats['q8_0']['cache_bytes_ratio']:.3f}; q4_0 "
        f"{q4 / b16:.2f}x at {formats['q4_0']['cache_bytes_ratio']:.3f})"))


def _kb_requests(cfg, seed: int = 11):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size,
                        size=int(rng.integers(*KB_PROMPT_RANGE))
                    ).astype(np.int32),
                    max_new_tokens=KB_MAX_NEW)
            for i in range(KB_REQUESTS)]


def _kb_pass(engine, cfg):
    reqs = _kb_requests(cfg)
    for r in reqs:
        engine.submit(r)
    tokens0 = engine.stats.tokens_generated
    prefills0 = engine.stats.prefills
    decode0 = engine.stats.decode_wall_s
    engine.run()
    tokens = engine.stats.tokens_generated - tokens0
    dec_tokens = tokens - (engine.stats.prefills - prefills0)
    return (engine.stats.decode_wall_s - decode0, dec_tokens, tokens,
            [r.output for r in reqs])


def _sweep_kernels(cfg, model, params, out, rows) -> None:
    """{q8_0, q4_0} weights+cache × {xla, pallas} kernel backends
    through the megastep engine, plus the analytic backend flip."""
    from repro.quant.quantize import quantize_tree
    params_by_fmt = {
        fmt: quantize_tree(params, fmt, cfg.quant_group)
        for fmt in KB_FORMATS}
    engines = {
        (fmt, be): ServingEngine(model, params_by_fmt[fmt], slots=SLOTS,
                                 max_len=KB_MAX_LEN,
                                 sampling=SamplingConfig(),  # greedy
                                 megastep_k=KB_K, admission="stall",
                                 megastep_unroll=True, quant_policy=fmt,
                                 kv_quant=fmt, kernels=be)
        for fmt in KB_FORMATS for be in KB_BACKENDS}
    best_dec = {key: float("inf") for key in engines}
    tokens, dec_tokens, outputs, n_passes = {}, {}, {}, {}
    for key, eng in engines.items():             # untimed: compilation
        _kb_pass(eng, cfg)
        eng.reset()
    for _ in range(KB_REPS):                     # interleave reps so
        for key, eng in engines.items():         # load hits all alike
            dec_dt, dec_tokens[key], tokens[key], outputs[key], n = \
                _timed_region(lambda e=eng: _kb_pass(e, cfg), 0,
                              passes=n_passes.get(key, 0))
            n_passes[key] = n
            best_dec[key] = min(best_dec[key], dec_dt)
            eng.reset()

    formats: Dict[str, Dict] = {}
    for fmt in KB_FORMATS:
        per_be = {}
        for be in KB_BACKENDS:
            key = (fmt, be)
            per_be[be] = {
                "decode_tok_s": round(dec_tokens[key] / best_dec[key], 1),
                "decode_wall_s": round(best_dec[key], 4),
                "tokens": tokens[key],
                "timed_passes": n_passes[key],
            }
        formats[fmt] = {
            **per_be,
            # the kernel contract this PR's parity suite pins: the
            # fused dequant kernels are greedy token-identical to the
            # XLA unpack path, so backend choice is pure performance
            "greedy_equiv_xla_pallas":
                outputs[(fmt, "xla")] == outputs[(fmt, "pallas")],
        }

    # analytic twin on TPU-class bandwidth: the planner prices both
    # backends; the fused kernels flip the q4-vs-q8 ordering (this is
    # the prediction a real-pod run would measure, not the interpret-
    # mode walls above)
    from repro.configs import INPUT_SHAPES, get_config as _get
    from repro.core import TPU_V5E, plan as _plan
    full = _get("deepseek-7b")
    plans = {be: _plan(full, INPUT_SHAPES["decode_32k"], TPU_V5E,
                       kernel_backend=be) for be in KB_BACKENDS}
    analytic = {be: {"quant_policy": plans[be].quant_policy,
                     "kv_quant": plans[be].kv_quant}
                for be in KB_BACKENDS}
    flip = (analytic["pallas"]["kv_quant"] == "q4_0"
            and analytic["xla"]["kv_quant"] == "q8_0")

    out["kernel_backend"] = {
        "requests": KB_REQUESTS, "max_new": KB_MAX_NEW,
        "max_len": KB_MAX_LEN, "megastep_k": KB_K, "slots": SLOTS,
        "sampling": "greedy", "admission": "stall",
        "min_timed_s": MIN_TIMED_S,
        "note": "pallas timings are interpret-mode on this CPU "
                "container; the portable claims are token-identity "
                "and the analytic ordering flip",
        "formats": formats,
        "analytic_tpu_v5e_decode_32k": analytic,
        "q4_flip_predicted": flip,
    }
    q4x = formats["q4_0"]["xla"]["decode_tok_s"]
    q4p = formats["q4_0"]["pallas"]["decode_tok_s"]
    rows.append((
        "serving/kernels_q4_pallas_over_xla", q4p / q4x * 100,
        f"q4_0 weights+cache: pallas {q4p:.0f} vs xla {q4x:.0f} decode "
        f"tok/s (interpret mode); token-identical: "
        f"{formats['q4_0']['greedy_equiv_xla_pallas']}; TPU planner "
        f"flip xla->q8_0 / pallas->q4_0: {flip}"))


def _sweep_megastep(cfg, model, params, out, rows) -> None:
    engines = {k: ServingEngine(model, params, slots=SLOTS, max_len=64,
                                sampling=SamplingConfig(),  # greedy →
                                megastep_k=k,               # comparable
                                admission="stall",   # PR-1 upfront-queue
                                megastep_unroll=True)
               for k in KS}
    best = {k: float("inf") for k in KS}
    best_dec = {k: float("inf") for k in KS}
    outputs, tokens, dec_tokens, n_passes = {}, {}, {}, {}
    for k in KS:                         # untimed pass pays compilation
        _pass(engines[k])
    for _ in range(REPS):                # interleave reps across K so
        for k in KS:                     # machine load hits all K alike
            dt, dec_dt, dec_tokens[k], tokens[k], outputs[k], n = \
                _timed_region(lambda e=engines[k]: _pass(e), 1,
                              passes=n_passes.get(k, 0))
            n_passes[k] = n
            best[k] = min(best[k], dt)
            best_dec[k] = min(best_dec[k], dec_dt)

    per_k = {}
    for k in KS:
        dt, dec_dt = best[k], best_dec[k]
        tok_s = tokens[k] / dt
        # decode-phase throughput isolates the dispatch-amortization
        # lever the sweep is about (prefill cost is identical across K)
        dec_tok_s = dec_tokens[k] / dec_dt
        total_passes = 1 + REPS * n_passes[k]
        dispatches = engines[k].stats.megasteps // total_passes
        per_k[k] = {"tok_s": round(tok_s, 1),
                    "decode_tok_s": round(dec_tok_s, 1),
                    "wall_s": round(dt, 4),
                    "decode_wall_s": round(dec_dt, 4),
                    "tokens": tokens[k],
                    "timed_passes": n_passes[k],
                    "dispatches": dispatches}
        prefill_batches = engines[k].stats.prefill_batches // total_passes
        rows.append((
            f"serving/megastep_k{k}", dec_dt / max(dispatches, 1) * 1e6,
            f"{tokens[k]} tokens in {dt:.2f}s = {tok_s:.0f} tok/s e2e, "
            f"{dec_tok_s:.0f} tok/s decode-phase "
            f"({prefill_batches} prefill batches)"))

    speedup = per_k[8]["decode_tok_s"] / per_k[1]["decode_tok_s"]
    equiv = outputs[8] == outputs[1]
    out.update({
        "bench": "serving_megastep_sweep",
        "model": "deepseek-7b reduced (2L, d64, ff128, v256)",
        "slots": SLOTS, "requests": N_REQUESTS, "max_new": MAX_NEW,
        "sampling": "greedy", "min_timed_s": MIN_TIMED_S,
        "per_k": {str(k): v for k, v in per_k.items()},
        "k8_over_k1_decode": round(speedup, 2),
        "k8_over_k1_e2e": round(per_k[8]["tok_s"] / per_k[1]["tok_s"], 2),
        "greedy_equiv_k8_k1": equiv,
    })
    rows.append(("serving/k8_over_k1_speedup", speedup * 100,
                 f"K=8 {speedup:.2f}x over K=1 (decode phase); greedy "
                 f"token-identical: {equiv}"))


def _sweep_mixed(cfg, model, params, out, rows) -> None:
    # -- mixed prefill/decode workload: stall vs chunked admission -------
    mix_engines = {
        mode: ServingEngine(model, params, slots=SLOTS, max_len=64,
                            sampling=SamplingConfig(), megastep_k=MIX_K,
                            admission=mode, megastep_unroll=True)
        for mode in ("stall", "chunked")}
    mixed = {}
    mix_outputs = {}
    mix_best = {}
    mix_passes = {}
    for mode, eng in mix_engines.items():
        _run_mixed(eng, cfg)             # untimed pass pays compilation
        eng.reset()
    for _ in range(MIX_REPS):            # interleave reps across modes
        for mode, eng in mix_engines.items():   # so machine load hits
            res = _timed_region(                # both alike
                lambda e=eng: _run_mixed(e, cfg), 0,
                passes=mix_passes.get(mode, 0))
            mix_passes[mode] = res[-1]
            if mode not in mix_best or res[0] < mix_best[mode][0]:
                mix_best[mode] = res
            mix_outputs[mode] = res[4]
            eng.reset()
    for mode in mix_engines:
        wall, dec_tokens, tokens, dispatches, _, n = mix_best[mode]
        mixed[mode] = {
            "decode_tok_s": round(dec_tokens / wall, 1),
            "tok_s": round(tokens / wall, 1),
            "wall_s": round(wall, 4),
            "tokens": tokens,
            "dispatches": dispatches,
            "timed_passes": n,
        }
    mix_ratio = mixed["chunked"]["decode_tok_s"] / \
        mixed["stall"]["decode_tok_s"]
    mix_equiv = mix_outputs["chunked"] == mix_outputs["stall"]

    out["mixed_workload"] = {
        "requests": MIX_REQUESTS, "max_new": MIX_MAX_NEW,
        "megastep_k": MIX_K, "slots": SLOTS,
        "arrivals": "seeded poisson-ish, gap 0-1 steps, "
                    "prompts 3-13 tokens",
        "min_timed_s": MIN_TIMED_S,
        **{mode: mixed[mode] for mode in ("stall", "chunked")},
        "chunked_over_stall_decode": round(mix_ratio, 2),
        "greedy_equiv_chunked_stall": mix_equiv,
    }
    rows.append((
        "serving/chunked_over_stall_mixed", mix_ratio * 100,
        f"mixed workload: chunked admission {mix_ratio:.2f}x over "
        f"stall-prefill decode-phase tok/s "
        f"({mixed['chunked']['dispatches']} vs "
        f"{mixed['stall']['dispatches']} dispatches); greedy "
        f"token-identical: {mix_equiv}"))


def _async_pass(engine) -> Dict:
    """One pass over the standard workload with per-pass deltas of the
    pipelining attribution stats."""
    reqs = _requests(ASYNC_REQUESTS, ASYNC_MAX_NEW)
    for r in reqs:
        engine.submit(r)
    st = engine.stats
    base = (st.decode_wall_s, st.drain_wait_s, st.megasteps,
            st.tokens_generated, st.prefills)
    engine.run()
    tokens = st.tokens_generated - base[3]
    return {
        "decode_wall_s": st.decode_wall_s - base[0],
        "drain_wait_s": st.drain_wait_s - base[1],
        "megasteps": st.megasteps - base[2],
        "dec_tokens": tokens - (st.prefills - base[4]),
        "outputs": [r.output for r in reqs],
    }


def _sweep_async(cfg, model, params, out, rows) -> None:
    """pipeline_depth {1, 2, 4} through one K=1 engine: decode tok/s,
    the per-megastep host dispatch/drain gap and its shrinkage, greedy
    token identity across depths."""
    # This sweep builds its own model, bigger than the shared 2L/d64
    # one: pipelining hides host work *behind the device step*, so the
    # device step must be comparable to the ~0.5-1ms host gap for
    # there to be anything to hide (on the shared model the device is
    # ~15us/megastep at K=1 — the measurable ceiling is ~2%). d256 at
    # 2 layers puts the K=1 device step at ~1ms, the balanced point;
    # much bigger (4L/ff1024) and the host blocks on deep in-flight
    # work instead, which this backend's partial background chaining
    # turns into a regression.
    cfg = reduced(get_config("deepseek-7b"), d_model=256, d_ff=512,
                  vocab_size=512, num_heads=4, num_kv_heads=2,
                  unroll_scans=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, slots=SLOTS, max_len=128,
                        sampling=SamplingConfig(),  # greedy
                        megastep_k=ASYNC_K, admission="chunked",
                        megastep_unroll=True, donate_carries=False)
    _async_pass(eng)                     # untimed pass pays compilation
    eng.reset()

    def _one():
        r = _async_pass(eng)
        return (r["decode_wall_s"], r["drain_wait_s"], r["megasteps"],
                r["dec_tokens"], r["outputs"])

    best = {d: None for d in ASYNC_DEPTHS}
    outputs = {}
    depth_passes = {}
    for _ in range(ASYNC_REPS):          # interleave reps across depths
        for d in ASYNC_DEPTHS:           # so load hits all alike
            eng.pipeline_depth = d
            dec, drain, megasteps, dec_tokens, outs, n = \
                _timed_region(_one, 0, passes=depth_passes.get(d, 0))
            depth_passes[d] = n
            res = {"decode_wall_s": dec, "drain_wait_s": drain,
                   "megasteps": megasteps, "dec_tokens": dec_tokens}
            outputs[d] = outs
            if best[d] is None or \
                    res["decode_wall_s"] < best[d]["decode_wall_s"]:
                best[d] = res
            eng.reset()

    # pipelining must move *time*, never tokens: greedy streams are
    # identical across depths (the property suite pins this across all
    # cache families; the bench asserts it on its own workload too)
    equiv = all(outputs[d] == outputs[ASYNC_DEPTHS[0]]
                for d in ASYNC_DEPTHS)
    assert equiv, "pipelined engine diverged from serial greedy tokens"

    depths: Dict[str, Dict] = {}
    for d in ASYNC_DEPTHS:
        b = best[d]
        m = max(b["megasteps"], 1)
        # the host gap: per-megastep host work (dispatch call, drain
        # python, admission staging) NOT spent blocked on the device —
        # the serial-loop overhead pipelining exists to hide. The
        # blocked share (drain_wait) may grow as depth rises: the host
        # runs ahead and waits on deeper in-flight work instead.
        gap_us = (b["decode_wall_s"] - b["drain_wait_s"]) / m * 1e6
        depths[f"depth{d}"] = {
            "decode_tok_s": round(b["dec_tokens"] / b["decode_wall_s"], 1),
            "decode_wall_s": round(b["decode_wall_s"], 4),
            "megasteps": b["megasteps"],
            "host_gap_us_per_megastep": round(gap_us, 1),
            "drain_wait_us_per_megastep": round(
                b["drain_wait_s"] / m * 1e6, 1),
            "timed_passes": depth_passes[d],
        }
    d_hi = ASYNC_DEPTHS[-1]
    gap1 = depths["depth1"]["host_gap_us_per_megastep"]
    gap_hi = depths[f"depth{d_hi}"]["host_gap_us_per_megastep"]
    ratio = depths[f"depth{d_hi}"]["decode_tok_s"] / \
        depths["depth1"]["decode_tok_s"]

    # analytic twin: the overlap model at the paper's 2-thread A17
    # point, same K — predicted period per megastep per depth (the
    # model saturates at depth 2: one in-flight megastep already hides
    # the gap up to the device-step time)
    from repro.core import a17_cpu, simulate_async_overlap
    sim = simulate_async_overlap(cfg, a17_cpu(2), k=ASYNC_K,
                                 depths=ASYNC_DEPTHS)
    analytic = {f"depth{d}": {
        "tok_s": round(sim[d].tokens_per_s, 1),
        "detail": sim[d].detail} for d in ASYNC_DEPTHS}

    out["async_overlap"] = {
        "model": "deepseek-7b reduced (2L, d256, ff512, v512) — "
                 "sized so the K=1 device step ~= the host gap",
        "requests": ASYNC_REQUESTS, "max_new": ASYNC_MAX_NEW,
        "megastep_k": ASYNC_K, "slots": SLOTS,
        "sampling": "greedy", "admission": "chunked",
        "donate_carries": False, "min_timed_s": MIN_TIMED_S,
        "note": "K=1 is the per-token-dispatch regime this sweep "
                "pipelines; donation is off because chained-carry "
                "donation serializes dispatch on this backend, and at "
                "K>=2 megastep amortization has already hidden the "
                "host gap (see benchmarks/serving_bench.py docstring)",
        "depths": depths,
        "host_gap_shrink": round(gap1 / max(gap_hi, 1e-9), 2),
        f"depth{d_hi}_over_depth1_decode": round(ratio, 2),
        "greedy_equiv_depths": equiv,
        "analytic_a17_2t": analytic,
    }
    rows.append((
        "serving/async_host_gap_depth%d" % d_hi, gap_hi,
        f"host gap/megastep {gap1:.0f}us (depth1) -> {gap_hi:.0f}us "
        f"(depth{d_hi}), {gap1 / max(gap_hi, 1e-9):.2f}x shrink; "
        f"decode {ratio:.2f}x; greedy token-identical: {equiv}"))


def _paging_requests(cfg, n: int, seed: int = 17, prefix_len: int = 0):
    rng = np.random.default_rng(seed)
    prefix = (rng.integers(1, cfg.vocab_size,
                           size=prefix_len).astype(np.int32)
              if prefix_len else None)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(*PAGING_PROMPT_RANGE))
        tail = rng.integers(1, cfg.vocab_size,
                            size=plen).astype(np.int32)
        prompt = np.concatenate([prefix, tail]) if prefix_len else tail
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=PAGING_MAX_NEW))
    return reqs


def _paging_pass(engine, cfg, n: int, prefix_len: int = 0):
    """One timed pass. Returns (decode wall, decode tokens, total
    tokens, outputs) — the _kv_pass shape."""
    reqs = _paging_requests(cfg, n, prefix_len=prefix_len)
    for r in reqs:
        engine.submit(r)
    st = engine.stats
    base = (st.decode_wall_s, st.tokens_generated, st.prefills)
    engine.run()
    tokens = st.tokens_generated - base[1]
    dec_tokens = tokens - (st.prefills - base[2])
    return (st.decode_wall_s - base[0], dec_tokens, tokens,
            [r.output for r in reqs])


def _paging_peak(engine, cfg, n: int):
    """Untimed step-driven pass sampling the pool's peak in-use
    blocks — the live-token footprint the dense layout can't shrink
    below its prealloc."""
    for r in _paging_requests(cfg, n):
        engine.submit(r)
    peak = 0
    while engine.has_work():
        engine.step()
        peak = max(peak, engine.blocks_in_use)
    return peak


def _paged_block_nbytes(engine) -> int:
    """Device bytes of one pool block (all layers, K+V payload +
    scale leaves)."""
    lay = engine.cache["layers"]
    return sum(lay[name].size * lay[name].dtype.itemsize
               // engine.cache_blocks
               for name in ("k", "v", "k_scale", "v_scale")
               if name in lay)


def _sweep_paging(cfg, model, params, out, rows) -> None:
    """Dense vs paged KV cache through the engine: token identity,
    the gather tax, cache bytes scaling with live tokens, and the
    shared-prefix copy-on-write admission win."""
    # pool sized to the workload's worst case, not slots x max_pages:
    # the allocated-bytes win over the dense prealloc is the point
    need = PAGING_PROMPT_RANGE[1] - 1 + PAGING_MAX_NEW
    blocks = {p: SLOTS * -(-need // p) + 1 for p in PAGE_SIZES}
    engines = {0: ServingEngine(model, params, slots=SLOTS,
                                max_len=PAGING_MAX_LEN,
                                sampling=SamplingConfig(),  # greedy
                                megastep_k=MIX_K, admission="chunked",
                                megastep_unroll=True)}
    for p in PAGE_SIZES:
        engines[p] = ServingEngine(model, params, slots=SLOTS,
                                   max_len=PAGING_MAX_LEN,
                                   sampling=SamplingConfig(),
                                   megastep_k=MIX_K,
                                   admission="chunked",
                                   megastep_unroll=True, page_size=p,
                                   cache_blocks=blocks[p])
    n_req = PAGING_LOADS[-1]
    best_dec = {key: float("inf") for key in engines}
    tokens, dec_tokens, outputs, n_passes = {}, {}, {}, {}
    for key, eng in engines.items():             # untimed: compilation
        _paging_pass(eng, cfg, n_req)
        eng.reset()
    for _ in range(PAGING_REPS):                 # interleave reps so
        for key, eng in engines.items():         # load hits all alike
            dec_dt, dec_tokens[key], tokens[key], outputs[key], n = \
                _timed_region(
                    lambda e=eng: _paging_pass(e, cfg, n_req), 0,
                    passes=n_passes.get(key, 0))
            n_passes[key] = n
            best_dec[key] = min(best_dec[key], dec_dt)
            eng.reset()

    dense_bytes = engines[0].cache_nbytes()
    dense_tok_s = dec_tokens[0] / best_dec[0]
    paged: Dict[str, Dict] = {}
    for p in PAGE_SIZES:
        paged[f"p{p}"] = {
            "decode_tok_s": round(dec_tokens[p] / best_dec[p], 1),
            "decode_wall_s": round(best_dec[p], 4),
            "tokens": tokens[p],
            "timed_passes": n_passes[p],
            "cache_bytes": engines[p].cache_nbytes(),
            "cache_blocks": blocks[p],
            # paging moves bytes, never tokens (the tentpole contract,
            # reference-pinned across archs in the property suite)
            "greedy_equiv_dense": outputs[p] == outputs[0],
        }

    # cache bytes vs live tokens: peak in-use pool blocks across
    # growing loads (the dense prealloc never moves)
    p0 = PAGE_SIZES[0]
    eng = engines[p0]
    block_b = _paged_block_nbytes(eng)
    fixed_b = eng.cache_nbytes() - block_b * eng.cache_blocks
    scaling = {}
    for load in PAGING_LOADS:
        eng.reset()
        peak = _paging_peak(eng, cfg, load)
        scaling[f"requests_{load}"] = {
            "peak_blocks": peak,
            "peak_live_tokens_ub": peak * p0,
            "peak_cache_bytes": peak * block_b + fixed_b,
        }
    eng.reset()

    # shared-prefix copy-on-write: every request opens with the same
    # system prompt; hits map its pages into the new slot's table and
    # the riders for those tokens vanish from admission
    pfx = ServingEngine(model, params, slots=SLOTS,
                        max_len=PAGING_MAX_LEN,
                        sampling=SamplingConfig(), megastep_k=MIX_K,
                        admission="chunked", megastep_unroll=True,
                        page_size=p0, prefix_cache=True)
    _paging_pass(pfx, cfg, PAGING_PREFIX_REQUESTS,
                 prefix_len=PAGING_PREFIX_LEN)   # untimed: compilation
    pfx.reset()
    h0 = (pfx.stats.prefix_hits, pfx.stats.prefix_hit_tokens)
    pfx_dec, pfx_dec_tokens, _pt, pfx_out, pfx_n = _timed_region(
        lambda: _paging_pass(pfx, cfg, PAGING_PREFIX_REQUESTS,
                             prefix_len=PAGING_PREFIX_LEN), 0)
    hits = pfx.stats.prefix_hits - h0[0]
    hit_tokens = pfx.stats.prefix_hit_tokens - h0[1]
    dense_out = _paging_pass(engines[0], cfg, PAGING_PREFIX_REQUESTS,
                             prefix_len=PAGING_PREFIX_LEN)[-1]
    prefix = {
        "prefix_len": PAGING_PREFIX_LEN,
        "requests": PAGING_PREFIX_REQUESTS,
        "page_size": p0,
        "decode_tok_s": round(pfx_dec_tokens / pfx_dec, 1),
        "decode_wall_s": round(pfx_dec, 4),
        "timed_passes": pfx_n,
        "prefix_hits": hits,
        "prefix_hit_tokens": hit_tokens,
        # each cached-prefix token is one rider substep the chunked
        # admission no longer spends
        "admission_substeps_saved": hit_tokens,
        "greedy_equiv_dense": pfx_out == dense_out,
    }

    # analytic twin at the paper's 2-thread A17 point, with and
    # without prefix reuse
    from repro.core import a17_cpu
    from repro.core.scheduler import simulate_paging
    mean_prompt = sum(PAGING_PROMPT_RANGE) // 2 + PAGING_PREFIX_LEN
    analytic = {}
    for tag, hit in (("hit0", 0.0), ("hit0.75", 0.75)):
        sim = simulate_paging(cfg, a17_cpu(2), slots=SLOTS, k=MIX_K,
                              prompt_len=mean_prompt,
                              max_new=PAGING_MAX_NEW,
                              kv_len=PAGING_MAX_LEN,
                              page_sizes=PAGE_SIZES, hit_rate=hit)
        analytic[tag] = {
            ("dense" if p == 0 else f"p{p}"): {
                "tok_s": round(r["step"].tokens_per_s, 1),
                "pool_bytes": round(r["pool_bytes"]),
                "rider_substeps_saved": round(
                    r["rider_substeps_saved"], 1)}
            for p, r in sim.items()}

    out["paging"] = {
        "requests": n_req, "max_new": PAGING_MAX_NEW,
        "max_len": PAGING_MAX_LEN, "megastep_k": MIX_K,
        "slots": SLOTS, "sampling": "greedy", "admission": "chunked",
        "min_timed_s": MIN_TIMED_S,
        "page_sizes": list(PAGE_SIZES),
        "dense": {
            "decode_tok_s": round(dense_tok_s, 1),
            "decode_wall_s": round(best_dec[0], 4),
            "tokens": tokens[0],
            "timed_passes": n_passes[0],
            "cache_bytes": dense_bytes,
        },
        "paged": paged,
        "bytes_vs_live_tokens": {
            "page_size": p0,
            "block_bytes": block_b,
            "dense_cache_bytes": dense_bytes,
            **scaling,
        },
        "prefix_cache": prefix,
        "analytic_a17_2t": analytic,
    }
    p8 = paged[f"p{p0}"]
    ratio = p8["decode_tok_s"] / round(dense_tok_s, 1)
    peak_hi = scaling[f"requests_{PAGING_LOADS[-1]}"]["peak_cache_bytes"]
    peak_lo = scaling[f"requests_{PAGING_LOADS[0]}"]["peak_cache_bytes"]
    rows.append((
        "serving/paging_p%d_over_dense" % p0, ratio * 100,
        f"paged p{p0} {p8['decode_tok_s']:.0f} vs dense "
        f"{dense_tok_s:.0f} decode tok/s (= {ratio:.2f}x gather tax); "
        f"token-identical: {p8['greedy_equiv_dense']}; allocated "
        f"{p8['cache_bytes']} vs dense {dense_bytes} bytes"))
    rows.append((
        "serving/paging_bytes_scaling", peak_hi / max(peak_lo, 1) * 100,
        f"peak live cache bytes {peak_lo} -> {peak_hi} as load "
        f"{PAGING_LOADS[0]} -> {PAGING_LOADS[-1]} requests (dense "
        f"fixed at {dense_bytes}); prefix cache: {hits} hits / "
        f"{hit_tokens} prompt tokens skipped, token-identical: "
        f"{prefix['greedy_equiv_dense']}"))


def _overload_calibrate(eng, cfg, *, min_s: float = 0.0):
    """Measure aggregate capacity: saturated queue, no deadlines.
    Returns (service_s per request, megasteps per request, passes)."""
    wall, steps, n, passes = 0.0, 0, 0, 0
    while passes == 0 or wall < min_s:
        eng.reset()
        rng = np.random.default_rng(OV_SEED)
        reqs = [Request(uid=i, prompt=rng.integers(
                    1, cfg.vocab_size, size=int(rng.integers(
                        *OV_PROMPT_RANGE))).astype(np.int32),
                    max_new_tokens=OV_MAX_NEW)
                for i in range(3 * OV_SLOTS)]
        for r in reqs:
            eng.submit(r)
        m0 = eng.stats.megasteps
        t0 = time.perf_counter()
        eng.run()
        wall += time.perf_counter() - t0
        steps += eng.stats.megasteps - m0
        n += len(reqs)
        passes += 1
    return wall / n, steps / n, passes


def _overload_trace(cfg, rng, lam, service_s):
    """Poisson arrivals in megastep ticks: (tick, prompt, deadline_s,
    uid). ``lam`` = arrivals per tick; deadlines are seconds (the
    engine's submit() semantics), drawn relative to measured service."""
    trace, t = [], 0.0
    for i in range(OV_REQUESTS):
        plen = int(rng.integers(*OV_PROMPT_RANGE))
        prompt = rng.integers(1, cfg.vocab_size,
                              size=plen).astype(np.int32)
        dl = float(rng.uniform(*OV_DEADLINE_RANGE)) * service_s
        trace.append((int(t), prompt, dl, i))
        t += rng.exponential(1.0 / lam)
    return trace


def _overload_replay(eng, trace, *, bounded: bool):
    """Replay one arrival trace. Bounded submits with deadlines (typed
    sheds counted, never fatal); unbounded submits without (nothing
    shed, nothing preempted) and scores the same deadlines host-side."""
    pend = collections.deque(trace)
    live = []                    # [req, deadline_s, t_submit, t_done]
    shed = 0
    pre0 = eng.stats.preemptions
    tick = 0
    t0 = time.perf_counter()
    while pend or eng.has_work():
        while pend and pend[0][0] <= tick:
            _, prompt, dl, uid = pend.popleft()
            req = Request(uid=uid, prompt=prompt,
                          max_new_tokens=OV_MAX_NEW,
                          deadline_s=dl if bounded else None)
            try:
                eng.submit(req)
            except SubmitReject:
                shed += 1
                continue
            live.append([req, dl, time.perf_counter(), None])
        eng.step()
        now = time.perf_counter()
        for e in live:
            if e[3] is None and e[0].done:
                e[3] = now
        tick += 1
    wall = time.perf_counter() - t0
    hit = [e for e in live if e[3] is not None and e[3] - e[2] <= e[1]]
    return {
        "wall": wall, "shed": shed, "admitted": len(live),
        "done": sum(1 for e in live if e[3] is not None),
        "hits": len(hit),
        "good_tokens": sum(len(e[0].output) for e in hit),
        "tokens": sum(len(e[0].output) for e in live),
        "preempts": eng.stats.preemptions - pre0,
        "latencies": [e[3] - e[2] for e in live if e[3] is not None],
    }


def _overload_point(eng, cfg, lam, service_s, *, bounded: bool):
    """One (arrival multiple, policy) measurement on the ≥MIN_TIMED_S
    floor; traces are seeded per pass so both policies replay identical
    arrival schedules, prompts, and deadlines."""
    tot = collections.Counter()
    lats, passes = [], 0
    while passes == 0 or tot["wall"] < MIN_TIMED_S:
        eng.reset()
        eng.max_queue = OV_QUEUE_BOUND if bounded else 0
        rng = np.random.default_rng(OV_SEED + 1 + passes)
        trace = _overload_trace(cfg, rng, lam, service_s)
        r = _overload_replay(eng, trace, bounded=bounded)
        lats += r.pop("latencies")
        tot.update(r)
        eng.audit()              # pool invariants hold after the storm
        passes += 1
    offered = tot["shed"] + tot["admitted"]
    return {
        "shed_rate": round(tot["shed"] / offered, 3),
        "preempt_rate": round(tot["preempts"] / max(tot["admitted"], 1),
                              3),
        "deadline_hit_rate": round(tot["hits"] / offered, 3),
        "goodput_tok_s": round(tot["good_tokens"] / tot["wall"], 1),
        "decode_tok_s": round(tot["tokens"] / tot["wall"], 1),
        "p95_latency_s": (round(float(np.percentile(lats, 95)), 4)
                          if lats else None),
        "offered": offered,
        "completed": tot["done"],
        "preemptions": tot["preempts"],
        "decode_wall_s": round(tot["wall"], 4),
        "timed_passes": passes,
    }


def _sweep_overload(cfg, model, params, out, rows) -> None:
    """Bounded admission (max_queue + deadlines + preemption) vs the
    unbounded baseline under Poisson arrivals past measured capacity:
    the overload-PR acceptance claim, measured."""
    eng = ServingEngine(model, params, slots=OV_SLOTS,
                        max_len=OV_MAX_LEN, sampling=SamplingConfig(),
                        megastep_k=OV_K, admission="chunked",
                        megastep_unroll=True, page_size=OV_PAGE,
                        cache_blocks=OV_BLOCKS)
    _overload_calibrate(eng, cfg)            # untimed: compilation
    service_s, steps_per_req, cal_passes = _overload_calibrate(
        eng, cfg, min_s=MIN_TIMED_S)
    capacity_rps = 1.0 / service_s

    points: Dict[str, Dict] = {}
    for mult in OV_MULTIPLES:
        lam = mult / steps_per_req           # arrivals per megastep
        pt = {"arrival_rps": round(mult * capacity_rps, 2)}
        for tag, bounded in (("bounded", True), ("unbounded", False)):
            pt[tag] = _overload_point(eng, cfg, lam, service_s,
                                      bounded=bounded)
        points[f"x{mult:g}"] = pt
    eng.max_queue = 0

    # analytic twin at the paper's 2-thread A17 point: does the napkin
    # model predict the measured shed-rate ordering across multiples?
    from repro.core import a17_cpu
    from repro.core.scheduler import simulate_overload
    sim = simulate_overload(cfg, a17_cpu(2), slots=OV_SLOTS, k=OV_K,
                            prompt_len=sum(OV_PROMPT_RANGE) // 2,
                            max_new=OV_MAX_NEW, page_size=OV_PAGE,
                            cache_blocks=OV_BLOCKS,
                            arrival_multiples=OV_MULTIPLES)
    pred_shed = [round(sim["sweep"][m]["bounded"]["shed_rate"], 3)
                 for m in OV_MULTIPLES]
    meas_shed = [points[f"x{m:g}"]["bounded"]["shed_rate"]
                 for m in OV_MULTIPLES]
    order_ok = (
        all(a <= b for a, b in zip(pred_shed, pred_shed[1:]))
        and all(a <= b for a, b in zip(meas_shed, meas_shed[1:]))
        and ((pred_shed[-1] > pred_shed[0])
             == (meas_shed[-1] > meas_shed[0])))

    g2b = points["x2"]["bounded"]["goodput_tok_s"]
    g2u = points["x2"]["unbounded"]["goodput_tok_s"]
    out["overload"] = {
        "slots": OV_SLOTS, "megastep_k": OV_K, "max_len": OV_MAX_LEN,
        "max_new": OV_MAX_NEW, "page_size": OV_PAGE,
        "cache_blocks": OV_BLOCKS, "queue_bound": OV_QUEUE_BOUND,
        "admission": "chunked", "sampling": "greedy",
        "arrivals_per_pass": OV_REQUESTS,
        "deadline_range_x_service": list(OV_DEADLINE_RANGE),
        "min_timed_s": MIN_TIMED_S,
        "capacity": {
            "service_s_per_request": round(service_s, 5),
            "capacity_rps": round(capacity_rps, 2),
            "megasteps_per_request": round(steps_per_req, 3),
            "calibration_passes": cal_passes,
        },
        "sweep": points,
        "analytic_a17_2t": {
            "capacity_rps": round(sim["capacity"]["capacity_rps"], 3),
            "max_live_requests": sim["capacity"]["max_live_requests"],
            "predicted_bounded_shed_rate": dict(
                zip([f"x{m:g}" for m in OV_MULTIPLES], pred_shed)),
        },
        "predicted_shed_order_matches": order_ok,
        "bounded_beats_unbounded_at_2x": g2b > g2u,
    }
    rows.append((
        "serving/overload_goodput_2x",
        g2b / max(g2u, 1e-9) * 100,
        f"bounded {g2b:.0f} vs unbounded {g2u:.0f} goodput tok/s at 2x "
        f"capacity (shed {points['x2']['bounded']['shed_rate']:.0%}, "
        f"preempt {points['x2']['bounded']['preempt_rate']:.2f}/req); "
        f"analytic shed ordering matches: {order_ok}"))
    rows.append((
        "serving/overload_shed_3x",
        meas_shed[-1] * 100,
        f"bounded shed rate across x1/x2/x3 capacity: "
        f"{meas_shed} (predicted {pred_shed}); unbounded p95 latency "
        f"{points[f'x{OV_MULTIPLES[-1]:g}']['unbounded']['p95_latency_s']}s vs bounded "
        f"{points[f'x{OV_MULTIPLES[-1]:g}']['bounded']['p95_latency_s']}s at 3x"))


_SWEEPS = ("megastep", "mixed", "precision", "kv", "kernels", "async",
           "paging", "overload")


def run(sweeps: Sequence[str] = _SWEEPS) -> List[Tuple[str, float, str]]:
    cfg, model, params = _build_model()
    path = pathlib.Path(__file__).resolve().parents[1] / \
        "BENCH_serving.json"
    # merge into the existing file so a single-sweep run never clobbers
    # the other sections' numbers
    out = json.loads(path.read_text()) if path.exists() else {}
    rows: List[Tuple[str, float, str]] = []
    if "megastep" in sweeps:
        _sweep_megastep(cfg, model, params, out, rows)
    if "mixed" in sweeps:
        _sweep_mixed(cfg, model, params, out, rows)
    if "precision" in sweeps:
        _sweep_precision(cfg, model, params, out, rows)
    if "kv" in sweeps:
        _sweep_kv(cfg, model, params, out, rows)
    if "kernels" in sweeps:
        _sweep_kernels(cfg, model, params, out, rows)
    if "async" in sweeps:
        _sweep_async(cfg, model, params, out, rows)
    if "paging" in sweeps:
        _sweep_paging(cfg, model, params, out, rows)
    if "overload" in sweeps:
        _sweep_overload(cfg, model, params, out, rows)
    path.write_text(json.dumps(out, indent=2) + "\n")
    rows.append(("serving/bench_json", 0.0,
                 f"wrote {path.name} sections: {', '.join(sweeps)}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", default="all",
                    choices=list(_SWEEPS) + ["all"],
                    help="which sweep to run (default: all)")
    args = ap.parse_args()
    sweeps = _SWEEPS if args.sweep == "all" else (args.sweep,)
    print("name,us_per_call,derived")
    for name, us, derived in run(sweeps):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
