"""End-to-end serving benchmark: the ServingEngine decoding batched
requests on a reduced model (live execution)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, SamplingConfig, ServingEngine


def run() -> List[Tuple[str, float, str]]:
    cfg = reduced(get_config("deepseek-7b"), num_layers=3, d_model=256,
                  d_ff=512)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, slots=4, max_len=128,
                           sampling=SamplingConfig(temperature=0.8,
                                                   top_k=50))
    for i in range(8):
        engine.submit(Request(uid=i,
                              prompt=np.arange(5 + i, dtype=np.int32) + 1,
                              max_new_tokens=16))
    t0 = time.perf_counter()
    engine.run()
    dt = time.perf_counter() - t0
    us = dt / max(engine.stats.steps, 1) * 1e6
    return [(
        "serving/engine_8req_4slots", us,
        f"{engine.stats.tokens_generated} tokens in {dt:.2f}s = "
        f"{engine.stats.tokens_generated / dt:.0f} tok/s "
        f"({engine.stats.prefills} prefills, {engine.stats.steps} steps)")]
