"""§Perf hillclimb driver (deliverable g/perf).

Baselines come from results/dryrun_single_pod.json. This driver runs
the named experiments — each a (config override | sharding ruleset)
variant of one of the three chosen (arch × shape) pairs — and appends
the measured roofline terms to results/hillclimb.json. EXPERIMENTS.md
§Perf narrates the hypothesis → change → before/after for each.

  PYTHONPATH=src python -m benchmarks.hillclimb --exp qwen_decode_tp2d
  PYTHONPATH=src python -m benchmarks.hillclimb --all
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "hillclimb.json")

# name -> (arch, shape, rules, overrides, hypothesis)
EXPERIMENTS = {
    # ---- pair 1: qwen1.5-110b x decode_32k (paper-representative:
    # batch decode is the paper's core workload) ------------------------
    "qwen_decode_baseline_v2": (
        "qwen1.5-110b", "decode_32k", "v2", {},
        "baseline: FSDP(embed@data) x TP(model) — paper-faithful v2"),
    "qwen_decode_tp2d": (
        "qwen1.5-110b", "decode_32k", "tp2d", {},
        "FSDP all-gathers ~200GB of weights per decode step; full 2-D "
        "TP should cut per-chip HBM traffic toward params/256 + cache "
        "and leave only activation all-reduces"),
    "qwen_decode_tp1d_q4": (
        "qwen1.5-110b", "decode_32k", "tp1d",
        {"quant_policy": "q4_0"},
        "iteration 2 after tp2d refuted: 1-D TP on model only; weights "
        "replicate across data, affordable at Q4 (3.9 GB/chip) — zero "
        "weight collectives, only per-layer activation all-reduces; "
        "predict memory ~5ms, collective ~4ms vs baseline 543ms step"),
    "qwen_decode_tp1d_bf16": (
        "qwen1.5-110b", "decode_32k", "tp1d", {},
        "ablation: tp1d without quantization — 13.75 GB/chip of "
        "replicated bf16 weights should blow the 16 GB HBM budget, "
        "showing Q4 is what makes the decode sharding feasible"),
    "qwen_decode_v2_q4": (
        "qwen1.5-110b", "decode_32k", "v2",
        {"quant_policy": "q4_0"},
        "ablation: Q4 alone on the v2 baseline — quantization shrinks "
        "the FSDP weight gathers too, separating the quant win from "
        "the sharding win"),
    # ---- pair 2: kimi-k2 x train_4k (most collective-bound combo) -----
    "kimi_train_baseline_v2": (
        "kimi-k2-1t-a32b", "train_4k", "v2", {},
        "baseline: MoE dispatch resharding data->expert dominates"),
    "kimi_train_cap10": (
        "kimi-k2-1t-a32b", "train_4k", "v2", {"capacity_factor": 1.0},
        "all-to-all bytes scale with expert capacity; cf 1.25->1.0 "
        "should cut the collective term ~20% at the cost of more drops"),
    "kimi_train_expert_data": (
        "kimi-k2-1t-a32b", "train_4k", "v2e", {},
        "shard experts over BOTH axes (384/256): each chip holds 1.5 "
        "experts, the token buffer reshards once instead of "
        "gather+scatter across model"),
    # ---- pair 3: recurrentgemma-2b x train_4k (worst useful-flop
    # ratio: the scan + local-attention mix) -----------------------------
    "rg_train_baseline_v2": (
        "recurrentgemma-2b", "train_4k", "v2", {},
        "baseline hybrid training"),
    "rg_train_noremat": (
        "recurrentgemma-2b", "train_4k", "v2", {"remat": False},
        "2.7B params fit easily at bs256; remat only burns 1/3 more "
        "FLOPs here — turning it off should cut the compute term 25%"),
    "rg_train_block1024": (
        "recurrentgemma-2b", "train_4k", "v2",
        {"attn_block": 1024, "remat": False},
        "local window 2048 with 512-blocks scans 5 kv blocks/q-chunk; "
        "1024-blocks scan 3 — fewer masked-out FLOPs and fewer "
        "scan-carry writes"),
    "rg_train_noseqpar": (
        "recurrentgemma-2b", "train_4k", "v2ns", {"remat": False},
        "iteration 2: the collective term survived remat-off, so it is "
        "not gradient traffic; hypothesis: seq@model residuals fight "
        "heads@model attention layouts, forcing an all-gather per "
        "block. Dropping sequence parallelism (activations replicated "
        "on seq, 168 MB/chip at bs256) should collapse the term"),
    "kimi_train_v2ens": (
        "kimi-k2-1t-a32b", "train_4k", "v2ens", {},
        "iteration 3: combine 2-axis expert sharding (kills the "
        "33.8 GB/layer expert-weight FSDP gathers) with no seq-parallel "
        "residuals (kills the per-block activation resharding)"),
    # ---- v3 regression, TPU analogue (paper Figs 8-10) ------------------
    "qwen_decode_v3_regression": (
        "qwen1.5-110b", "decode_32k", "v3", {},
        "the paper's V3: attention and FFN sharded on different axes — "
        "the collective term should explode vs v2, reproducing the "
        "15->6 tk/s cliff structurally"),
}


def run_experiment(name: str) -> dict:
    arch, shape, rules, overrides, hypothesis = EXPERIMENTS[name]
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    code = textwrap.dedent(f"""
        from repro.launch.dryrun import run_one
        import json
        r = run_one({arch!r}, {shape!r}, rules_version={rules!r},
                    overrides={overrides!r}, verbose=False)
        print("RESULT::" + json.dumps(r, default=str))
    """)
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True, env=env,
                          timeout=3000)
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT::"):
            r = json.loads(line[len("RESULT::"):])
            r["experiment"] = name
            r["hypothesis"] = hypothesis
            return r
    return {"experiment": name, "ok": False,
            "error": proc.stderr[-1500:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    names = list(EXPERIMENTS) if args.all else [args.exp]
    existing = []
    if os.path.exists(RESULTS):
        existing = json.load(open(RESULTS))
    done = {r.get("experiment") for r in existing}
    for name in names:
        if name in done:
            print(f"skip {name} (already in {RESULTS})")
            continue
        print(f"=== {name}")
        r = run_experiment(name)
        existing.append(r)
        with open(RESULTS, "w") as f:
            json.dump(existing, f, indent=1, default=str)
        if r.get("ok"):
            t = r["roofline"]
            print(f"  compute={t['compute_s']:.2e} "
                  f"memory={t['memory_s']:.2e} "
                  f"collective={t['collective_s']:.2e} "
                  f"dom={t['dominant']}")
        else:
            print("  FAILED:", r.get("error", "")[:300])


if __name__ == "__main__":
    main()
