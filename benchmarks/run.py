# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper figure/table plus live
microbenchmarks and the TPU roofline table.

  PYTHONPATH=src python -m benchmarks.run [--only fig4,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module substrings")
    args = ap.parse_args()

    from benchmarks import (decode_microbench, fig4_throughput,
                            fig5_op_breakdown, fig6_matmul_breakdown,
                            fig8_scheduler_versions, roofline_table,
                            serving_bench)
    modules = [fig4_throughput, fig5_op_breakdown, fig6_matmul_breakdown,
               fig8_scheduler_versions, decode_microbench, serving_bench,
               roofline_table]
    if args.only:
        keys = args.only.split(",")
        modules = [m for m in modules
                   if any(k in m.__name__ for k in keys)]

    print("name,us_per_call,derived")
    failures = 0
    for mod in modules:
        try:
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},nan,ERROR", file=sys.stdout)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
