"""Roofline table (deliverable g): per (arch × shape) three-term
roofline from the compiled dry-run artifacts.

Reads results/dryrun_single_pod.json if the sweep has been run
(PYTHONPATH=src python -m repro.launch.dryrun --all --out
results/dryrun_single_pod.json); otherwise compiles a representative
subset inline (kept small so benchmarks/run.py stays fast).
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Tuple

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_single_pod.json")

_INLINE_SUBSET = [("deepseek-7b", "decode_32k"),
                  ("mamba2-2.7b", "train_4k")]


def _row(r) -> Tuple[str, float, str]:
    name = f"roofline/{r['arch']}/{r['shape']}"
    if not r.get("ok"):
        return (name, 0.0, f"FAILED: {r.get('error', '?')[:80]}")
    t = r["roofline"]
    return (
        name,
        r.get("compile_s", 0.0) * 1e6,
        (f"compute={t['compute_s']:.2e}s memory={t['memory_s']:.2e}s "
         f"collective={t['collective_s']:.2e}s dom={t['dominant']} "
         f"useful_flops={r['useful_flop_ratio'] * 100:.0f}% "
         f"peak_gb={r.get('peak_bytes', 0) / 1e9:.1f}"))


def run() -> List[Tuple[str, float, str]]:
    if os.path.exists(RESULTS):
        with open(RESULTS) as f:
            results = json.load(f)
        return [_row(r) for r in results]
    # inline fallback: compile a 2-combo subset in a subprocess (the
    # dry-run needs its own XLA_FLAGS before jax init)
    import subprocess
    import sys
    rows = []
    for arch, shape in _INLINE_SUBSET:
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape],
            capture_output=True, text=True,
            env={**os.environ,
                 "PYTHONPATH": os.path.join(os.path.dirname(__file__),
                                            "..", "src")})
        us = (time.perf_counter() - t0) * 1e6
        ok = "1/1 combos compiled OK" in proc.stdout
        rows.append((f"roofline/{arch}/{shape}", us,
                     "compiled-ok" if ok else "FAILED"))
    return rows
