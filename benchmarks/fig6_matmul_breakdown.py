"""Fig 6 reproduction: per-matmul cost within a decoder layer.

Paper: the FFN pair (ffn_up / ffn_down, plus gate) is the heaviest of
the seven per-layer GEMMs in both phases.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs.paper_models import LLAMA32_1B
from repro.core import profile_phases


def run() -> List[Tuple[str, float, str]]:
    t0 = time.perf_counter()
    profs = profile_phases(LLAMA32_1B, threads=2)
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for phase, prof in profs.items():
        total = sum(prof.by_matmul_tag.values())
        parts = sorted(prof.by_matmul_tag.items(), key=lambda kv: -kv[1])
        detail = " ".join(f"{k}={v / total * 100:.0f}%" for k, v in parts
                          if k != "lm_head")
        rows.append((f"fig6/{phase}", us / 2,
                     f"dominant={prof.dominant_matmul()} | {detail}"))
    return rows
