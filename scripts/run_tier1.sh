#!/usr/bin/env bash
# Tier-1 verification wrapper: the full pytest suite (including the
# serving property suite, tests/test_serving_properties.py) with a
# pinned hypothesis seed/profile so runs are deterministic in CI.
#
# With hypothesis installed, tests/_hypothesis_compat.py loads a
# derandomized profile; without it (this container), the compat shim's
# seeded fallback runner draws the identical example stream from
# REPRO_HYP_SEED. REPRO_HYP_EXAMPLES caps examples per property test
# (useful for quick smokes: REPRO_HYP_EXAMPLES=2 scripts/run_tier1.sh).
#
# Usage: scripts/run_tier1.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export REPRO_HYP_SEED="${REPRO_HYP_SEED:-0}"
export REPRO_PALLAS_INTERPRET="${REPRO_PALLAS_INTERPRET:-1}"

exec python -m pytest -x -q "$@"
