#!/usr/bin/env bash
# Tier-1 verification wrapper: the pytest suite with a pinned
# hypothesis seed/profile so runs are deterministic in CI — followed
# by seeded q4_0 weight-quant, q8_0 kv-cache, async front-end and
# paged-serving (prefix-hit admission + cancel-recycle) and chaos
# (pool exhaustion + poisoned logits + recovery under audit) smokes,
# and a schema check of the committed BENCH_serving.json (the
# precision, kv_precision, kernel_backend, async_overlap, paging and
# overload sections must be present:
# benchmarks/serving_bench.py --sweep ... writes them).
#
# By default the *fast* tier runs: pytest.ini excludes tests marked
# `slow` (the cross-arch serving property sweeps that push the full
# suite to ~24 min on this container). Pass --full to clear the
# marker filter and run everything — the pre-merge tier.
#
# With hypothesis installed, tests/_hypothesis_compat.py loads a
# derandomized profile; without it (this container), the compat shim's
# seeded fallback runner draws the identical example stream from
# REPRO_HYP_SEED. REPRO_HYP_EXAMPLES caps examples per property test
# (useful for quick smokes: REPRO_HYP_EXAMPLES=2 scripts/run_tier1.sh).
#
# Usage: scripts/run_tier1.sh [--full] [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export REPRO_HYP_SEED="${REPRO_HYP_SEED:-0}"
export REPRO_PALLAS_INTERPRET="${REPRO_PALLAS_INTERPRET:-1}"

MARKER_ARGS=()
if [[ "${1:-}" == "--full" ]]; then
    shift
    MARKER_ARGS=(-m "")     # clear pytest.ini's "not slow" filter
fi

python -m pytest -x -q "${MARKER_ARGS[@]}" "$@"

echo "[tier1] q4_0 quantized-serving smoke (seeded)"
python - <<'EOF'
import jax, numpy as np
from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, ServingEngine

cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
              vocab_size=256, num_heads=2, num_kv_heads=1)
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=4,
                    quant_policy="q4_0")
rng = np.random.default_rng(0)
reqs = [Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=5).astype(np.int32),
                max_new_tokens=6) for i in range(3)]
for r in reqs:
    eng.submit(r)
eng.run()
for r in reqs:
    assert r.done, r.uid
    ref = m.reference_decode(eng.params, r.prompt, r.max_new_tokens)
    assert r.output == ref, (r.uid, r.output, ref)
print(f"[tier1] q4_0 smoke OK: {len(reqs)} requests token-identical "
      f"to the quantized reference")
EOF

echo "[tier1] q8_0 kv-cache serving smoke (seeded)"
python - <<'EOF'
import jax, numpy as np
from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, ServingEngine

cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
              vocab_size=256, num_heads=2, num_kv_heads=1)
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=4,
                    kv_quant="q8_0")
assert eng.kv_quant == "q8_0"
import jax.numpy as jnp
assert any(l.dtype == jnp.int8
           for l in jax.tree_util.tree_leaves(eng.cache)), \
    "kv_quant engine must hold an int8 cache"
rng = np.random.default_rng(1)
reqs = [Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=6) for i in range(3)]
for r in reqs:
    eng.submit(r)
eng.run()
for r in reqs:
    assert r.done, r.uid
    # same quantized-cache path: the rebound model's reference loop
    ref = eng.model.reference_decode(eng.params, r.prompt,
                                     r.max_new_tokens)
    assert r.output == ref, (r.uid, r.output, ref)
print(f"[tier1] kv-quant smoke OK: {len(reqs)} requests token-identical "
      f"to the quantized-cache reference")
EOF

echo "[tier1] async-serve front-end smoke (deadlines + cancellation)"
python - <<'EOF'
import asyncio
import jax, numpy as np
from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.launch.serve import AsyncServingFrontend, DeadlineExceeded

cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
              vocab_size=256, num_heads=2, num_kv_heads=1)
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=2,
                    pipeline_depth=2)
rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, size=5 + i).astype(np.int32)
           for i in range(3)]

async def drive():
    fe = AsyncServingFrontend(eng, max_pending=4)
    streamed = []
    # one request with an impossible deadline: must raise
    # DeadlineExceeded and retire its slot via engine.cancel
    expired = 0
    try:
        await fe.generate(prompts[0], max_new_tokens=500,
                          deadline_s=0.0)
    except DeadlineExceeded:
        expired += 1
    # one explicit task cancellation mid-flight
    victim = asyncio.ensure_future(
        fe.generate(prompts[1], max_new_tokens=500))
    await asyncio.sleep(0.05)
    victim.cancel()
    try:
        await victim
    except asyncio.CancelledError:
        pass
    # a normal request afterwards: streams and completes correctly
    toks = await fe.generate(prompts[2], max_new_tokens=6,
                             deadline_s=30.0,
                             on_token=streamed.append)
    await fe.close()
    return expired, toks, streamed

expired, toks, streamed = asyncio.run(drive())
assert expired == 1, "deadline-expired request did not raise"
assert eng.stats.cancelled >= 2, eng.stats.cancelled
assert toks == streamed == m.reference_decode(params, prompts[2], 6)
assert eng.in_flight == 0 and not eng.has_work()
print(f"[tier1] async-serve smoke OK: 1 deadline expiry + 1 "
      f"cancellation ({eng.stats.cancelled} engine cancels), "
      f"survivor token-identical to reference")
EOF

echo "[tier1] paged-serving smoke (prefix-hit admission + cancel-recycle)"
python - <<'EOF'
import jax, numpy as np
from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, ServingEngine

cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
              vocab_size=256, num_heads=2, num_kv_heads=1)
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(2)
shared = rng.integers(1, cfg.vocab_size, size=17).astype(np.int32)
prompts = [np.concatenate([shared, rng.integers(
               1, cfg.vocab_size, size=3 + i).astype(np.int32)])
           for i in range(5)]

def serve(page, prefix):
    eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=4,
                        admission="chunked", prefill_chunk=16,
                        page_size=page, prefix_cache=prefix)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return eng, [r.output for r in reqs]

dense_eng, dense_out = serve(0, False)
eng, paged_out = serve(8, True)
assert paged_out == dense_out, "paged+prefix tokens diverged from dense"
hits = eng.stats.prefix_hits
assert hits >= 1, "no prefix-hit admission occurred"
assert eng.stats.prefix_hit_tokens >= 8, eng.stats.prefix_hit_tokens
# only the registry's own references survive the drain
assert eng.blocks_in_use == len(eng._prefix_reg) > 0, \
    (eng.blocks_in_use, len(eng._prefix_reg))

# cancel-recycle: retire a mid-decode slot and confirm its private
# blocks return to the free list while shared prefix pages stay live
eng.reset()
a = Request(uid=10, prompt=prompts[0], max_new_tokens=24)
b = Request(uid=11, prompt=prompts[1], max_new_tokens=6)
eng.submit(a)
eng.submit(b)
while not a.output and not a.done:
    eng.step()
live_mid = eng.blocks_in_use
assert eng.cancel(a)
assert eng.blocks_in_use < live_mid, "cancel freed no blocks"
eng.run()
assert b.output == dense_out[1], "cancel corrupted the neighbour slot"
assert eng.blocks_in_use == len(eng._prefix_reg), \
    "cancel leaked (or over-freed) cache blocks"
print(f"[tier1] paged smoke OK: 5 requests token-identical to dense, "
      f"{hits} prefix hit(s), cancel recycled blocks "
      f"({eng.blocks_in_use} registry-held blocks live after drain)")
EOF

echo "[tier1] chaos smoke (pool exhaustion + poisoned logits + recovery)"
python - <<'EOF'
import jax, numpy as np
from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import (FaultEvent, FaultInjector, FaultSchedule,
                           Request, ServingEngine)

cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
              vocab_size=256, num_heads=2, num_kv_heads=1)
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
# 12 usable blocks fully back the 3 slots; contention comes from the
# exhaust_pool fault quarantining most of the pool mid-flight
eng = ServingEngine(m, params, slots=3, max_len=64, megastep_k=4,
                    admission="chunked", prefill_chunk=16,
                    page_size=8, cache_blocks=13)
rng = np.random.default_rng(3)
reqs = [Request(uid=i, prompt=rng.integers(
            1, cfg.vocab_size, size=int(rng.integers(4, 14))
        ).astype(np.int32), max_new_tokens=8) for i in range(5)]
for r in reqs:
    eng.submit(r)
sched = FaultSchedule([
    FaultEvent(0, "poison_logits", ridx=2),     # sticks to uid 2
    FaultEvent(1, "exhaust_pool", blocks=9, duration=2),
    FaultEvent(4, "preempt", ridx=0),
])
inj = FaultInjector(eng, sched, audit=True, sleep=lambda s: None)
inj.run(reqs)                    # audits after every step
assert not eng.has_work() and not eng._quarantined
assert eng.blocks_in_use == len(eng._prefix_reg), "blocks leaked"
assert reqs[2].error == "nonfinite-logits", reqs[2].error
assert eng.stats.poisoned == 1 and eng.stats.preemptions >= 1
for r in reqs:
    assert r.done, r.uid
    ref = m.reference_decode(params, r.prompt, r.max_new_tokens)
    if r.error is None:
        assert r.output == ref, (r.uid, r.output, ref)
    else:                        # pre-poison tokens: clean ref prefix
        assert r.output == ref[:len(r.output)], r.uid
print(f"[tier1] chaos smoke OK: pool exhausted+recovered, 1 poisoned "
      f"retire, {eng.stats.preemptions} preemption(s), survivors "
      f"token-identical, audit held for {inj.steps_run} steps")
EOF

echo "[tier1] BENCH_serving.json schema check"
python - <<'EOF'
import json, pathlib
bench = json.loads(pathlib.Path("BENCH_serving.json").read_text())
for key in ("per_k", "k8_over_k1_decode", "mixed_workload", "precision",
            "kv_precision", "kernel_backend"):
    assert key in bench, f"BENCH_serving.json missing section: {key}"
prec = bench["precision"]
for key in ("formats", "q4_over_bf16_k8_decode", "analytic_a17_2t"):
    assert key in prec, f"precision section missing key: {key}"
for fmt in ("bf16", "q8_0", "q4_0"):
    assert fmt in prec["formats"], f"precision.formats missing {fmt}"
    for k in ("k1", "k8"):
        row = prec["formats"][fmt][k]
        assert "decode_tok_s" in row and row["decode_tok_s"] > 0, (fmt, k)
    assert prec["formats"][fmt]["greedy_equiv_k8_k1"] is True, \
        f"{fmt}: greedy K-invariance broken"
kv = bench["kv_precision"]
for key in ("formats", "q8_over_bf16_k8_decode", "q4_over_bf16_k8_decode",
            "analytic_a17_2t"):
    assert key in kv, f"kv_precision section missing key: {key}"
expected_ratio = {"bf16": 1.0, "q8_0": 8.5 / 16, "q4_0": 4.5 / 16}
for fmt in ("bf16", "q8_0", "q4_0"):
    assert fmt in kv["formats"], f"kv_precision.formats missing {fmt}"
    row = kv["formats"][fmt]
    for k in ("k1", "k8"):
        assert row[k]["decode_tok_s"] > 0, (fmt, k)
    # int8 payload + groupwise scales must land at ~bits/16 of bf16
    # (small slack: the int32 lens leaf doesn't shrink)
    assert abs(row["cache_bytes_ratio"] - expected_ratio[fmt]) < 0.02, \
        (fmt, row["cache_bytes_ratio"])
    assert row["greedy_equiv_k8_k1"] is True, \
        f"kv {fmt}: greedy K-invariance broken"
kb = bench["kernel_backend"]
for key in ("formats", "analytic_tpu_v5e_decode_32k",
            "q4_flip_predicted"):
    assert key in kb, f"kernel_backend section missing key: {key}"
for fmt in ("q8_0", "q4_0"):
    row = kb["formats"][fmt]
    for be in ("xla", "pallas"):
        assert row[be]["decode_tok_s"] > 0, (fmt, be)
    # the fused-kernel contract: backend choice never changes tokens
    assert row["greedy_equiv_xla_pallas"] is True, \
        f"kernel_backend {fmt}: xla/pallas token streams diverged"
# the planner's predicted ordering flip (xla -> q8_0, pallas -> q4_0)
assert kb["analytic_tpu_v5e_decode_32k"]["xla"]["kv_quant"] == "q8_0"
assert kb["analytic_tpu_v5e_decode_32k"]["pallas"]["kv_quant"] == "q4_0"
assert kb["q4_flip_predicted"] is True
pg = bench["paging"]
for key in ("page_sizes", "dense", "paged", "bytes_vs_live_tokens",
            "prefix_cache", "analytic_a17_2t", "min_timed_s"):
    assert key in pg, f"paging section missing key: {key}"
assert pg["min_timed_s"] >= 0.15, pg["min_timed_s"]
assert pg["dense"]["decode_tok_s"] > 0
assert pg["dense"]["decode_wall_s"] >= pg["min_timed_s"], \
    "paging dense timed region shorter than the floor"
for p in pg["page_sizes"]:
    row = pg["paged"][f"p{p}"]
    assert row["decode_tok_s"] > 0 and row["cache_blocks"] > 0, p
    assert row["decode_wall_s"] >= pg["min_timed_s"], \
        f"paging p{p} timed region shorter than the floor"
    # paged pool allocation stays under the dense prealloc
    assert row["cache_bytes"] < pg["dense"]["cache_bytes"], p
    assert row["greedy_equiv_dense"] is True, \
        f"paging p{p}: tokens diverged from the dense cache"
bl = pg["bytes_vs_live_tokens"]
loads = sorted(int(k.split("_")[1]) for k in bl if k.startswith("requests_"))
assert len(loads) >= 2, "need >=2 load points to show byte scaling"
peaks = [bl[f"requests_{n}"]["peak_cache_bytes"] for n in loads]
assert peaks[0] < peaks[-1] <= bl["dense_cache_bytes"], \
    f"paged peak bytes must grow with live tokens under dense: {peaks}"
pc = pg["prefix_cache"]
assert pc["prefix_hits"] > 0 and pc["prefix_hit_tokens"] > 0
assert pc["greedy_equiv_dense"] is True, \
    "prefix cache: tokens diverged from the dense cache"
ao = bench["async_overlap"]
for key in ("depths", "host_gap_shrink", "greedy_equiv_depths",
            "analytic_a17_2t"):
    assert key in ao, f"async_overlap section missing key: {key}"
for d in ("depth1", "depth2", "depth4"):
    row = ao["depths"][d]
    for k in ("decode_tok_s", "host_gap_us_per_megastep",
              "drain_wait_us_per_megastep"):
        assert row[k] > 0, (d, k)
# pipelining must shrink the host gap and never move tokens
assert ao["host_gap_shrink"] > 1.0, ao["host_gap_shrink"]
assert ao["greedy_equiv_depths"] is True, \
    "async_overlap: pipelined greedy tokens diverged from depth 1"
ov = bench["overload"]
for key in ("capacity", "sweep", "analytic_a17_2t", "queue_bound",
            "predicted_shed_order_matches",
            "bounded_beats_unbounded_at_2x", "min_timed_s"):
    assert key in ov, f"overload section missing key: {key}"
assert ov["capacity"]["capacity_rps"] > 0
for mult, pt in ov["sweep"].items():
    for pol in ("bounded", "unbounded"):
        row = pt[pol]
        assert row["decode_wall_s"] >= ov["min_timed_s"], \
            f"overload {mult}/{pol} timed region shorter than the floor"
        assert 0.0 <= row["shed_rate"] <= 1.0, (mult, pol)
        assert row["goodput_tok_s"] >= 0, (mult, pol)
    assert pt["unbounded"]["shed_rate"] == 0.0, \
        f"unbounded baseline shed requests at {mult}"
    assert pt["unbounded"]["preempt_rate"] == 0.0, \
        f"unbounded baseline preempted (no deadlines -> no EDF) at {mult}"
# the overload-PR acceptance claim: shedding + preemption beat the
# unbounded queue's goodput collapse past capacity, and the analytic
# twin gets the shed-rate ordering right
assert ov["bounded_beats_unbounded_at_2x"] is True, \
    "bounded admission lost to the unbounded baseline at 2x capacity"
assert ov["predicted_shed_order_matches"] is True, \
    "simulate_overload mispredicted the measured shed-rate ordering"
print("[tier1] BENCH_serving.json schema OK "
      f"(q4/bf16 @K8 decode = {prec['q4_over_bf16_k8_decode']}; "
      f"kv q8/bf16 @K8 = {kv['q8_over_bf16_k8_decode']}; "
      f"paged peak bytes {peaks[0]} -> {peaks[-1]} vs dense "
      f"{bl['dense_cache_bytes']})")
EOF
