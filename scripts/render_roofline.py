"""Render EXPERIMENTS.md §Roofline table from the sweep JSON.

  PYTHONPATH=src python scripts/render_roofline.py [results/dryrun_single_pod.json]
"""
import json
import sys


def bottleneck_fix(r) -> str:
    """One sentence on what would move the dominant term down."""
    dom = r["roofline"]["dominant"]
    kind = r["kind"]
    arch = r["arch"]
    if dom == "collective":
        if "moe" in arch or "kimi" in arch or "phi" in arch:
            return ("cut all-to-all: lower capacity_factor / 2-D expert "
                    "sharding")
        if kind == "train":
            return ("overlap grad all-reduce with bwd; reduce-scatter "
                    "instead of all-gather+local")
        return "decode-TP (tp2d) rules: stop FSDP weight gathers per step"
    if dom == "memory":
        if kind in ("decode",):
            return "quantize weights (q4_0) and/or KV cache to int8"
        return "larger microbatch per chip; fuse elementwise into GEMMs"
    return "raise arithmetic intensity: bigger per-chip tiles / batch"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "results/dryrun_single_pod.json"
    rs = json.load(open(path))
    print("| arch | shape | compute_s | memory_s | collective_s | dominant"
          " | MODEL_FLOPS/chip | useful | peak GB | next lever |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rs:
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | FAIL {r.get('error','')[:40]} |")
            continue
        t = r["roofline"]
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | "
              f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | "
              f"**{t['dominant']}** | {r['model_flops_per_chip']:.2e} | "
              f"{min(r['useful_flop_ratio'], 9.99)*100:.0f}% | "
              f"{r.get('peak_bytes', 0)/2**30:.1f} | "
              f"{bottleneck_fix(r)} |")


if __name__ == "__main__":
    main()
