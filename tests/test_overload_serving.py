"""Overload-safe serving: typed admission rejects, EDF queue ordering,
block-pool preemption/resume, poisoned-slot isolation, the allocator
audit, and the fault injector's retry machinery.

Chaos *sweeps* (seeded schedules x engine dimensions) live in
test_chaos_properties.py; this file pins each mechanism individually
with hand-built orderings — including the two cancel-vs-preemption
interleavings that used to double-free blocks.
"""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import (EngineAuditError, FaultEvent, FaultInjector,
                           FaultSchedule, InfeasibleDeadline,
                           PromptTooLong, QueueFull, Request,
                           ServingEngine, SubmitReject,
                           TransientStepFault)

_STATE = {}


def _model():
    if "m" not in _STATE:
        cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                      vocab_size=256, num_heads=2, num_kv_heads=1)
        m = Model(cfg)
        _STATE["m"] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _STATE["m"]


def _engine(key, **kw):
    if key not in _STATE:
        cfg, m, params = _model()
        _STATE[key] = ServingEngine(m, params, **kw)
    eng = _STATE[key]
    eng.reset()
    return eng


def _req(uid, n_prompt=6, max_new=6, **kw):
    return Request(uid=uid,
                   prompt=(np.arange(n_prompt, dtype=np.int32) % 200)
                   + 1 + uid,
                   max_new_tokens=max_new, **kw)


# -- typed rejects ---------------------------------------------------------

def test_submit_rejects_oversized_prompt_dense():
    """A prompt longer than the dense full-attention cache used to be
    accepted and corrupt the slot's rows at prefill — now a typed
    reject at admission."""
    eng = _engine("dense2", slots=2, max_len=32)
    with pytest.raises(PromptTooLong) as ei:
        eng.submit(_req(0, n_prompt=40))
    assert isinstance(ei.value, SubmitReject)
    assert isinstance(ei.value, ValueError)     # old catch sites hold
    assert ei.value.reason == "prompt_too_long"
    # prompt == capacity is legal; max_new past capacity rings legally
    eng.submit(_req(1, n_prompt=32, max_new=4))
    assert len(eng.queue) == 1


def test_submit_rejects_prompt_exceeding_paged_pool():
    eng = _engine("paged_tiny", slots=2, max_len=64, page_size=8,
                  cache_blocks=5)
    with pytest.raises(PromptTooLong):
        # needs ceil((30+10)/8)=5 pages > 4 usable: can never admit
        eng.submit(_req(0, n_prompt=30, max_new=10))


def test_submit_oversized_prompt_ok_for_recurrent():
    """SSM state is O(1) in sequence length — long prompts are legal
    there and must not be shed."""
    cfg = reduced(get_config("mamba2-2.7b"))
    m = Model(cfg)
    eng = ServingEngine(m, m.init(jax.random.PRNGKey(0)), slots=1,
                        max_len=16)
    eng.submit(Request(uid=0, prompt=np.ones(40, np.int32),
                       max_new_tokens=2))
    assert len(eng.queue) == 1


def test_queue_full_sheds_with_metadata():
    eng = _engine("bounded", slots=1, max_len=32, max_queue=2)
    eng.submit(_req(0))
    eng.submit(_req(1))
    with pytest.raises(QueueFull) as ei:
        eng.submit(_req(2))
    assert ei.value.reason == "queue_full"
    assert ei.value.queue_depth == 2
    assert ei.value.retry_after_s is None      # nothing measured yet
    assert eng.stats.shed == 1
    # drain, then the hint comes from the measured substep rate
    eng.run()
    eng.submit(_req(3))
    eng.submit(_req(4))
    with pytest.raises(QueueFull) as ei:
        eng.submit(_req(5))
    assert ei.value.retry_after_s is not None
    assert ei.value.retry_after_s > 0.0


def test_infeasible_deadline_sheds():
    eng = _engine("bounded", slots=1, max_len=32, max_queue=2)
    with pytest.raises(InfeasibleDeadline) as ei:
        eng.submit(_req(0, deadline_s=0.0))
    assert ei.value.reason == "infeasible_deadline"
    assert eng.stats.shed == 1
    # a generous deadline admits (and completes) fine
    r = _req(1, deadline_s=60.0)
    eng.submit(r)
    eng.run()
    assert r.done and len(r.output) == 6


def test_edf_queue_ordering():
    """Deadline requests drain earliest-first; deadline-less ones stay
    FIFO behind every deadline."""
    eng = _engine("dense2", slots=2, max_len=32)
    r_fifo = _req(0)
    r_late = _req(1, deadline_s=60.0)
    r_soon = _req(2, deadline_s=5.0)
    for r in (r_fifo, r_late, r_soon):
        eng.submit(r)
    assert [r.uid for r in eng.queue] == [2, 1, 0]


# -- preemption / resume ---------------------------------------------------

def test_preempt_resume_token_identical():
    cfg, m, params = _model()
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    r = _req(0, n_prompt=8, max_new=8)
    ref = m.reference_decode(params, r.prompt, 8)
    eng.submit(r)
    eng.step()
    eng.step()                      # some decode progress
    assert eng.preempt(r)
    assert r.preemptions == 1
    assert any(q is r for q in eng.queue)
    eng.audit()
    eng.run()
    eng.audit()
    assert r.done and r.output == ref
    assert eng.stats.preemptions == 1
    assert eng.blocks_in_use == 0   # nothing leaked


def test_pool_starved_admission_preempts_later_deadline_victim():
    """An EDF-earlier arrival evicts a later-deadline occupant when the
    pool can't back both; the victim resumes token-identical."""
    cfg, m, params = _model()
    # 8 usable blocks; each request needs ceil((8+8)/8)=2 pages; keep
    # 6 quarantined so only one request fits at a time
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    victim = _req(0, n_prompt=8, max_new=8, deadline_s=120.0)
    urgent = _req(1, n_prompt=8, max_new=8, deadline_s=30.0)
    refs = {r.uid: m.reference_decode(params, r.prompt, 8)
            for r in (victim, urgent)}
    eng.submit(victim)
    eng.step()                      # victim occupies the pool
    assert eng.quarantine_blocks(6) == 6
    eng.submit(urgent)
    eng.step()                      # urgent's admission must preempt
    eng.audit()
    assert victim.preemptions == 1
    eng.release_quarantined()
    eng.run()
    eng.audit()
    for r in (victim, urgent):
        assert r.done and r.error is None
        assert r.output == refs[r.uid], r.uid
    assert eng.blocks_in_use == 0


def test_fifo_overload_blocks_instead_of_preempting():
    """Same-class (deadline-less) traffic must never preempt — the
    EDF-key guard: a queued arrival is younger than every active
    request, so pool exhaustion blocks FIFO instead of thrashing."""
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    eng.quarantine_blocks(6)
    a, b = _req(0, n_prompt=8, max_new=8), _req(1, n_prompt=8, max_new=8)
    eng.submit(a)
    eng.submit(b)
    eng.step()
    assert a.preemptions == 0 and b.preemptions == 0
    assert any(q is b for q in eng.queue)     # blocked, not preempting
    eng.release_quarantined()
    eng.run()
    assert a.done and b.done and eng.stats.preemptions == 0


# -- cancel x preemption orderings (the double-free regression) ------------

def test_cancel_after_preempt_is_clean_noop():
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    r = _req(0, n_prompt=8, max_new=8)
    eng.submit(r)
    eng.step()
    assert eng.preempt(r)           # blocks recycled, requeued
    eng.audit()
    used = eng.blocks_in_use
    assert eng.cancel(r) is True    # queue path — must not re-release
    eng.audit()
    assert eng.blocks_in_use == used
    assert r.cancelled and not any(q is r for q in eng.queue)
    eng.run()
    eng.audit()


def test_preempt_after_cancel_refuses():
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    r = _req(0, n_prompt=8, max_new=8)
    eng.submit(r)
    eng.step()
    assert eng.cancel(r) is True
    eng.audit()
    assert eng.preempt(r) is False  # no slot, no double-release
    eng.audit()
    assert eng.stats.preemptions == 0
    eng.run()


# -- poisoned-request isolation --------------------------------------------

def test_poisoned_request_isolated_from_batch():
    cfg, m, params = _model()
    eng = _engine("dense2k4", slots=2, max_len=64, megastep_k=4)
    good, bad = _req(0, n_prompt=6, max_new=8), _req(7, n_prompt=5,
                                                     max_new=8)
    ref = m.reference_decode(params, good.prompt, 8)
    eng.submit(good)
    eng.submit(bad)
    eng.inject_logit_poison(bad)
    eng.run()
    assert bad.done and bad.error == "nonfinite-logits"
    assert eng.stats.poisoned == 1
    # the co-batched survivor is byte-identical to a clean run
    assert good.done and good.error is None and good.output == ref
    # and the engine serves the next wave normally
    nxt = _req(20, n_prompt=6, max_new=8)
    eng.submit(nxt)
    eng.run()
    assert nxt.output == m.reference_decode(params, nxt.prompt, 8)


def test_poison_mid_stream_keeps_clean_prefix():
    cfg, m, params = _model()
    eng = _engine("dense2k4", slots=2, max_len=64, megastep_k=4)
    r = _req(0, n_prompt=6, max_new=12)
    ref = m.reference_decode(params, r.prompt, 12)
    eng.submit(r)
    eng.step()                      # emits some clean tokens first
    eng.inject_logit_poison(r)
    eng.run()
    assert r.error == "nonfinite-logits"
    assert len(r.output) < 12
    assert r.output == ref[:len(r.output)]


# -- audit + quarantine ----------------------------------------------------

def test_audit_catches_refcount_corruption():
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    r = _req(0, n_prompt=8, max_new=8)
    eng.submit(r)
    eng.step()
    eng.audit()
    blk = eng._slot_blocks[0][0]
    eng._ref[blk] += 1              # simulate a leaked reference
    with pytest.raises(EngineAuditError):
        eng.audit()
    eng._ref[blk] -= 1
    eng.audit()
    eng.run()


def test_audit_catches_double_ownership():
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    r = _req(0, n_prompt=8, max_new=8)
    eng.submit(r)
    eng.step()
    blk = eng._slot_blocks[0][0]
    eng._free.append(blk)           # referenced AND free
    with pytest.raises(EngineAuditError):
        eng.audit()
    eng._free.remove(blk)
    eng.audit()
    eng.run()


def test_quarantine_is_audited_owner_class():
    eng = _engine("paged9", slots=2, max_len=64, page_size=8,
                  cache_blocks=9, megastep_k=4)
    took = eng.quarantine_blocks(3)
    assert took == 3
    eng.audit()                     # partition holds mid-quarantine
    assert eng.release_quarantined(1) == 1
    eng.audit()
    assert eng.release_quarantined() == 2
    eng.audit()
    assert len(eng._free) == 8


# -- fault injector --------------------------------------------------------

def test_transient_fault_retries_and_recovers():
    cfg, m, params = _model()
    eng = _engine("dense2k4", slots=2, max_len=64, megastep_k=4)
    r = _req(0, n_prompt=6, max_new=8)
    ref = m.reference_decode(params, r.prompt, 8)
    eng.submit(r)
    naps = []
    inj = FaultInjector(
        eng, FaultSchedule([FaultEvent(0, "step_exception", fires=2)]),
        max_retries=3, backoff_s=0.001, sleep=naps.append)
    inj.run([r])
    assert r.done and r.output == ref
    assert inj.retries == 2
    assert naps == [0.001, 0.002]   # exponential backoff, bounded


def test_transient_fault_exhausts_retries():
    eng = _engine("dense2k4", slots=2, max_len=64, megastep_k=4)
    r = _req(0, n_prompt=6, max_new=8)
    eng.submit(r)
    inj = FaultInjector(
        eng, FaultSchedule([FaultEvent(0, "step_exception", fires=9)]),
        max_retries=2, backoff_s=0.0, sleep=lambda s: None)
    with pytest.raises(TransientStepFault):
        inj.run([r])
    assert inj.retries == 2
    eng.reset()


def test_seeded_schedule_is_reproducible():
    a = FaultSchedule.seeded(42, n_requests=4)
    b = FaultSchedule.seeded(42, n_requests=4)
    assert a.events == b.events
    c = FaultSchedule.seeded(43, n_requests=4)
    assert a.events != c.events
    d = FaultSchedule.seeded(7, n_requests=3, paged=False)
    assert all(e.kind != "exhaust_pool" for e in d.events)


# -- front-end surfacing ---------------------------------------------------

def test_frontend_backpressure_carries_retry_hint():
    from repro.launch.serve import AsyncServingFrontend, Backpressure
    eng = _engine("bounded1", slots=1, max_len=32, max_queue=1,
                  megastep_k=4)

    async def drive():
        fe = AsyncServingFrontend(eng, max_pending=8,
                                  drain_hint_s=0.25)
        p = np.asarray([1, 2, 3], np.int32)
        tasks = [asyncio.ensure_future(
            fe.generate(p, max_new_tokens=4)) for _ in range(3)]
        out = await asyncio.gather(*tasks, return_exceptions=True)
        await fe.close()
        return out

    out = asyncio.run(drive())
    shed = [e for e in out if isinstance(e, Backpressure)]
    done = [t for t in out if isinstance(t, list)]
    assert shed and done            # some shed, some served
    assert all(e.retry_after_s is not None and e.retry_after_s > 0
               for e in shed)       # hint from drain_hint_s fallback
    assert all(len(t) == 4 for t in done)


def test_frontend_surfaces_poisoned_request_failure():
    from repro.launch.serve import AsyncServingFrontend, RequestFailed
    cfg, m, params = _model()
    eng = _engine("dense2k4", slots=2, max_len=64, megastep_k=4)

    async def drive():
        fe = AsyncServingFrontend(eng, max_pending=4)
        p = np.asarray([1, 2, 3, 4], np.int32)
        task = asyncio.ensure_future(
            fe.generate(p, max_new_tokens=6))
        while not fe._live:          # wait for admission
            await asyncio.sleep(0.001)
        eng.inject_logit_poison(fe._live[0].req)
        try:
            await task
            return None
        except RequestFailed as e:
            return e
        finally:
            await fe.close()

    err = asyncio.run(drive())
    assert err is not None
    assert err.reason == "nonfinite-logits"


def test_parser_overload_knobs():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args([])
    assert args.max_queue == 0 and args.audit is False
    args = build_parser().parse_args(["--max-queue", "8", "--audit"])
    assert args.max_queue == 8 and args.audit is True


# -- analytic twin ---------------------------------------------------------

def test_simulate_overload_bounded_beats_unbounded_past_capacity():
    from repro.core import simulate_overload
    cfg = get_config("deepseek-7b")
    ov = simulate_overload(cfg, slots=4, prompt_len=16, max_new=16,
                           page_size=8, cache_blocks=9)
    cap = ov["capacity"]
    assert cap["capacity_rps"] > 0
    assert cap["drain_s_per_request"] == pytest.approx(
        1.0 / cap["capacity_rps"])
    sweep = ov["sweep"]
    for m_, pt in sweep.items():
        b, u = pt["bounded"], pt["unbounded"]
        assert u["shed_rate"] == 0.0
        if m_ <= 1.0:
            assert b["shed_rate"] == 0.0
        else:
            # past capacity: shedding holds goodput, unbounded decays
            assert b["shed_rate"] > 0.0
            assert b["goodput_tok_s"] > u["goodput_tok_s"]
    # shed rate grows with arrival rate
    sheds = [sweep[m_]["bounded"]["shed_rate"] for m_ in sorted(sweep)]
    assert sheds == sorted(sheds)


def test_plan_emits_queue_bound_only_past_capacity():
    from repro.configs.base import InputShape
    from repro.core import plan
    cfg = get_config("deepseek-7b")
    sh = InputShape("decode_s", 64, 4, "decode")
    hot = plan(cfg, sh, arrival_rate_per_s=1000.0, avg_prompt_len=16,
               max_new=16)
    assert hot.max_queue > 0
    cold = plan(cfg, sh, arrival_rate_per_s=1e-4, avg_prompt_len=16,
                max_new=16)
    assert cold.max_queue == 0
    if hot.page_size:
        assert hot.cache_blocks > 0
    assert "max_queue" in hot.summary()
