"""End-to-end behaviour: train a tiny model, checkpoint it, serve it.

This is the full paper pipeline in miniature — training substrate →
quantization (the paper's Q4/Q8 study) → batched serving (the paper's
decode benchmark), all through the public API.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, reduced
from repro.core import plan
from repro.models import Model
from repro.quant import quantize_tree
from repro.serving import Request, SamplingConfig, ServingEngine
from repro.training import (AdamWConfig, DataConfig, TrainConfig, batches,
                            checkpoint, init_state, make_train_step)


def test_train_quantize_serve_pipeline(tmp_path):
    cfg = dataclasses.replace(reduced(get_config("deepseek-7b")),
                              param_dtype="f32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # 1. train
    tcfg = TrainConfig(adamw=AdamWConfig(lr=2e-3, warmup_steps=5,
                                         total_steps=200))
    step = jax.jit(make_train_step(model, tcfg))
    opt = init_state(params)
    it = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=8, kind="copy"))
    first = last = None
    for i in range(40):
        params, opt, metrics = step(
            params, opt,
            {k: jnp.asarray(v) for k, v in next(it).items()})
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first

    # 2. checkpoint round trip
    path = str(tmp_path / "model.msgpack")
    checkpoint.save(path, params)
    params = checkpoint.restore(path)

    # 3. quantize per the paper's Q8 setting and serve batched requests
    qparams = quantize_tree(
        jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), params),
        "q8_0")
    qcfg = dataclasses.replace(cfg, quant_policy="q8_0")
    engine = ServingEngine(Model(qcfg), qparams, slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + 1,
                    max_new_tokens=8) for i in range(4)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done and len(r.output) == 8 for r in reqs)

    # 4. greedy outputs of quantized vs full model mostly agree
    engine_f = ServingEngine(model, params, slots=1, max_len=64)
    rf = Request(uid=9, prompt=np.arange(4, dtype=np.int32) + 1,
                 max_new_tokens=8)
    engine_f.submit(rf)
    engine_f.run()
    agree = np.mean([a == b for a, b in zip(rf.output, reqs[0].output)])
    assert agree >= 0.5, (rf.output, reqs[0].output)


def test_dispatch_plan_configures_model():
    """The hardware-aware planner's overrides produce a runnable model."""
    cfg = get_config("deepseek-7b")
    p = plan(cfg, INPUT_SHAPES["decode_32k"])
    over = p.config_overrides()
    assert over["fuse_qkv"] is True
    # kernels wins over use_pallas in __post_init__, so pin both to
    # keep this smoke test on the fast XLA path
    small = dataclasses.replace(
        reduced(cfg), **{**over, "use_pallas": False, "kernels": "xla"})
    m = Model(small)
    params = m.init(jax.random.PRNGKey(0))
    logits, _ = m.forward(params, {"tokens": jnp.zeros((2, 8), jnp.int32)})
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert p.summary()  # human-readable report exists
