"""Property-based serving equivalence suite.

The continuous-batching engine (chunked prefill admission, per-slot
sampling, donated megastep carries) must be **token-identical** under
greedy decoding to a single-request reference decode loop
(``Model.reference_decode``), across randomized prompt lengths,
``max_new``, EOS positions, megastep K ∈ {1, 4, 8}, slot counts and
queue depths — and across weight precisions: the quantized tests hold
a q8_0/q4_0 engine to the reference run under the *same* quantized
params (tolerance-aware in the sense that quantization may legally
change tokens vs bf16, but never engine-vs-reference). The KV-cache
precision dimension (``cfg.kv_quant``) gets the same treatment: a
quantized-cache engine is pinned to the quantized-cache reference
across all four cache families and both admission modes, and is a
verified no-op for the recurrent families. Runs under
``tests/_hypothesis_compat``: with hypothesis installed it uses the
deterministic ``repro_ci`` profile; without it, the shim's seeded
fallback runner draws the same examples every time.

Engines and models are cached per configuration (``ServingEngine.reset``
keeps compiled executables), so each example pays jit cost only once
per (arch, slots, K, admission) combination.
"""
import dataclasses
import os

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.models import Model
from repro.quant import quantize_tree
from repro.serving import (Request, SamplingConfig, ServingEngine,
                           sample, sample_batched)

ARCHS = ("deepseek-7b", "mistral-nemo-12b", "mamba2-2.7b",
         "recurrentgemma-2b")
QUANTS = ("q8_0", "q4_0")
RECURRENT_ARCHS = ("mamba2-2.7b", "recurrentgemma-2b")

_MODELS = {}
_ENGINES = {}


def _model(arch, quant="bf16", kv="bf16"):
    key = (arch, quant, kv)
    if key not in _MODELS:
        cfg = reduced(get_config(arch))
        if cfg.arch_type == "dense":
            # tiny dense variant keeps the suite fast; recurrent archs
            # stay at reduced() (their state shapes don't shrink well)
            cfg = reduced(get_config(arch), d_model=64, d_ff=128,
                          vocab_size=256, num_heads=2, num_kv_heads=1)
        if kv != "bf16":
            cfg = dataclasses.replace(cfg, kv_quant=kv)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        if quant != "bf16":
            params = quantize_tree(params, quant, cfg.quant_group)
        _MODELS[key] = (cfg, m, params)
    return _MODELS[key]


def _engine(arch, slots, k, mode, quant="bf16", kv="bf16",
            kernels=None, page=0, prefix=False) -> ServingEngine:
    key = (arch, slots, k, mode, quant, kv, kernels, page, prefix)
    if key not in _ENGINES:
        cfg, m, params = _model(arch, quant, kv)
        _ENGINES[key] = ServingEngine(
            m, params, slots=slots, max_len=64, megastep_k=k,
            admission=mode, prefill_chunk=16, kernels=kernels,
            page_size=page, prefix_cache=prefix)
    eng = _ENGINES[key]
    eng.reset()
    # pipeline_depth is host-side orchestration over the same compiled
    # executable, so the async dimension mutates it on cached engines;
    # restore the serial default for every other test
    eng.pipeline_depth = 1
    return eng


def _random_requests(cfg, rng, n, max_prompt=14, max_new_hi=12):
    return [Request(
        uid=i,
        prompt=rng.integers(1, cfg.vocab_size, size=int(
            rng.integers(1, max_prompt))).astype(np.int32),
        max_new_tokens=int(rng.integers(1, max_new_hi)))
        for i in range(n)]


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 4, 8]),
       st.integers(1, 3), st.integers(1, 5))
@settings(max_examples=8, deadline=None)
def test_chunked_engine_matches_reference(seed, k, slots, n_req):
    """Continuous-batching greedy output == per-request reference loop,
    for any (prompt length, max_new, K, slots, queue depth)."""
    cfg, m, params = _model("deepseek-7b")
    rng = np.random.default_rng(seed)
    reqs = _random_requests(cfg, rng, n_req)
    eng = _engine("deepseek-7b", slots, k, "chunked")
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.stats.prefill_batches == 0    # admission stayed in-scan
    for r in reqs:
        assert r.done
        ref = m.reference_decode(params, r.prompt, r.max_new_tokens)
        assert r.output == ref, (r.uid, r.output, ref)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 4, 8]))
@settings(max_examples=6, deadline=None)
def test_eos_retires_exactly_at_reference_position(seed, k):
    """Pick an EOS from the reference stream: the engine must stop the
    slot exactly there, wherever it lands inside a megastep block."""
    cfg, m, params = _model("deepseek-7b")
    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, cfg.vocab_size, size=int(
        rng.integers(1, 14))).astype(np.int32)
    ref = m.reference_decode(params, prompt, 16)
    eos = ref[int(rng.integers(0, len(ref)))]
    idx = ref.index(eos)
    eng = _engine("deepseek-7b", 2, k, "chunked")
    req = Request(uid=0, prompt=prompt, max_new_tokens=16, eos_id=eos)
    eng.submit(req)
    eng.run()
    assert req.done
    assert req.output == ref[:idx + 1]


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 4, 8]))
@settings(max_examples=6, deadline=None)
def test_admission_modes_equivalent(seed, k):
    """Chunked in-scan admission and stall (batched-prefill) admission
    produce identical greedy tokens — on this backend the two prefill
    paths are bit-identical for attention caches."""
    cfg, m, params = _model("deepseek-7b")
    outs = {}
    for mode in ("chunked", "stall"):
        rng = np.random.default_rng(seed)
        reqs = _random_requests(cfg, rng, int(rng.integers(2, 6)))
        eng = _engine("deepseek-7b", 2, k, mode)
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        outs[mode] = [r.output for r in reqs]
    assert outs["chunked"] == outs["stall"]


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(ARCHS))
@settings(max_examples=4, deadline=None)
def test_chunked_matches_reference_across_archs(seed, arch):
    """Every cache family (full attention, sliding-window ring, SSM
    state, RG-LRU state) admits correctly through the scan: chunk
    refills + advance_mask writes reproduce the reference loop."""
    cfg, m, params = _model(arch)
    rng = np.random.default_rng(seed)
    reqs = _random_requests(cfg, rng, 3, max_prompt=24, max_new_hi=8)
    eng = _engine(arch, 2, 8, "chunked")
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert r.done
        ref = m.reference_decode(params, r.prompt, r.max_new_tokens)
        assert r.output == ref, (arch, r.uid, r.output, ref)


@pytest.mark.slow  # ~2 min: cross-arch x format x admission sweep
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(QUANTS))
@settings(max_examples=3, deadline=None)
def test_quantized_engine_matches_reference(seed, quant):
    """Tolerance-aware quantized-serving property (paper §5.3):
    quantization may change *which* greedy tokens come out relative to
    bf16 — that drift is bounded by the per-format roundtrip error and
    is not a defect — but the continuous-batching engine must stay
    **token-identical** to ``Model.reference_decode`` run under the
    *same* quantized params. Deterministic inner loop covers all four
    cache families × both admission modes per drawn example, so one
    passing run certifies the full acceptance grid.

    The oracle's prefill path matches the engine's admission mode:
    chunked admission feeds prompts through ``decode_step`` (stepwise
    reference), stall admission through the fused ``prefill``. Under
    bf16 the two prefill paths never flipped a greedy token on this
    backend (ROADMAP PR-2 note); under q4_0 the recurrent archs'
    associative-vs-sequential scan rounding *does* flip greedy tokens,
    so each mode is pinned to its own path's reference."""
    rng = np.random.default_rng(seed)
    for arch in ARCHS:
        cfg, m, params = _model(arch, quant)
        for mode in ("chunked", "stall"):
            reqs = _random_requests(cfg, rng, 2, max_prompt=8,
                                    max_new_hi=6)
            eng = _engine(arch, 2, 4, mode, quant)
            for r in reqs:
                eng.submit(r)
            eng.run()
            for r in reqs:
                assert r.done
                ref = m.reference_decode(
                    params, r.prompt, r.max_new_tokens,
                    stepwise_prefill=(mode == "chunked"))
                assert r.output == ref, (arch, mode, quant, r.uid,
                                         r.output, ref)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(QUANTS),
       st.sampled_from([4, 8]))
@settings(max_examples=3, deadline=None)
def test_quantized_megastep_k_invariance(seed, quant, k):
    """Greedy K-invariance must survive quantization: a q8_0/q4_0
    engine at megastep K produces the same tokens as K=1 (the frozen
    write mask + scan-over-layers slicing of QuantizedTensor leaves
    cannot depend on K)."""
    rng = np.random.default_rng(seed)
    reqs_spec = [(rng.integers(1, 256, size=int(rng.integers(1, 10)))
                  .astype(np.int32), int(rng.integers(1, 10)))
                 for _ in range(3)]
    outs = {}
    for kk in (1, k):
        eng = _engine("deepseek-7b", 2, kk, "chunked", quant)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(reqs_spec)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs[kk] = [r.output for r in reqs]
    assert outs[1] == outs[k], (quant, k)


@pytest.mark.slow  # ~3 min: cache-family x format x admission x K sweep
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(QUANTS),
       st.sampled_from([1, 4, 8]))
@settings(max_examples=3, deadline=None)
def test_kv_quant_engine_matches_reference(seed, kv, k):
    """KV-cache precision property (the PR-4 tentpole): a q8_0/q4_0
    cache may legally change *which* greedy tokens come out relative to
    bf16 (roundtrip drift through the attention read), but the engine
    must stay token-identical to ``Model.reference_decode`` run with
    the *same* ``cfg.kv_quant`` — same quantized cache-write path —
    across all four cache families × both admission modes × megastep
    K ∈ {1, 4, 8}. As with q4_0 weights (ROADMAP PR-3 note), each
    admission mode is pinned to its own prefill path's reference
    (fused-prefill and stepwise cache writes quantize identically for
    attention archs, but the recurrent families' bf16 no-op path keeps
    the associative-vs-sequential gap)."""
    rng = np.random.default_rng(seed)
    for arch in ARCHS:
        cfg, m, params = _model(arch, kv=kv)
        for mode in ("chunked", "stall"):
            reqs = _random_requests(cfg, rng, 2, max_prompt=8,
                                    max_new_hi=6)
            eng = _engine(arch, 2, k, mode, kv=kv)
            for r in reqs:
                eng.submit(r)
            eng.run()
            for r in reqs:
                assert r.done
                ref = m.reference_decode(
                    params, r.prompt, r.max_new_tokens,
                    stepwise_prefill=(mode == "chunked"))
                assert r.output == ref, (arch, mode, kv, k, r.uid,
                                         r.output, ref)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(QUANTS))
@settings(max_examples=2, deadline=None)
def test_kv_quant_noop_for_recurrent_families(seed, kv):
    """SSM / RG-LRU state leaves stay bf16 under any ``kv_quant``
    (recurrent state is small and precision-sensitive): structurally —
    no int8 leaf appears in the cache — and behaviourally — the token
    streams are identical to the bf16-cache engine's."""
    rng = np.random.default_rng(seed)
    for arch in RECURRENT_ARCHS:
        cfg, m, params = _model(arch, kv=kv)
        assert m.kv_quant_effective() == "bf16"
        cache = m.init_cache(2, 64)
        assert all(l.dtype != jnp.int8
                   for l in jax.tree_util.tree_leaves(cache)), arch
        reqs_spec = [(rng.integers(1, cfg.vocab_size, size=int(
            rng.integers(1, 10))).astype(np.int32),
            int(rng.integers(1, 8))) for _ in range(2)]
        outs = {}
        for kv_mode in ("bf16", kv):
            eng = _engine(arch, 2, 8, "chunked", kv=kv_mode)
            reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                    for i, (p, n) in enumerate(reqs_spec)]
            for r in reqs:
                eng.submit(r)
            eng.run()
            outs[kv_mode] = [r.output for r in reqs]
        assert outs["bf16"] == outs[kv], (arch, kv)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(QUANTS))
@settings(max_examples=3, deadline=None)
def test_kv_quant_eos_retires_at_reference_position(seed, kv):
    """EOS positions under a quantized cache: pick an EOS from the
    quantized-cache reference stream; the engine must stop exactly
    there, wherever it lands inside a megastep block."""
    cfg, m, params = _model("deepseek-7b", kv=kv)
    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, cfg.vocab_size, size=int(
        rng.integers(1, 14))).astype(np.int32)
    ref = m.reference_decode(params, prompt, 16)
    eos = ref[int(rng.integers(0, len(ref)))]
    idx = ref.index(eos)
    eng = _engine("deepseek-7b", 2, 4, "chunked", kv=kv)
    req = Request(uid=0, prompt=prompt, max_new_tokens=16, eos_id=eos)
    eng.submit(req)
    eng.run()
    assert req.done
    assert req.output == ref[:idx + 1]


@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(("bf16",) + QUANTS), st.sampled_from([1, 8]))
@settings(max_examples=2, deadline=None)
def test_pallas_engine_matches_reference(seed, quant, k):
    """Cross-backend token identity (the fused-kernel contract this
    PR's kernels were debugged against): a ``kernels="pallas"`` engine
    — quant_matmul decode GEMVs + the quantized decode-attention
    kernel, interpret mode on CPU — produces the same greedy tokens as
    ``Model.reference_decode`` on the plain XLA model, for the same
    params and cache format, across both admission modes and megastep
    K ∈ {1, 8}. The cache format rides the weight format (quantized
    weights + quantized cache is the fused kernel's target regime)."""
    rng = np.random.default_rng(seed)
    kv = "bf16" if quant == "bf16" else quant
    cfg, m, params = _model("deepseek-7b", quant, kv)
    for mode in ("chunked", "stall"):
        reqs = _random_requests(cfg, rng, 2, max_prompt=8, max_new_hi=6)
        eng = _engine("deepseek-7b", 2, k, mode, quant, kv,
                      kernels="pallas")
        assert eng.kernels == "pallas"
        for r in reqs:
            eng.submit(r)
        eng.run()
        for r in reqs:
            assert r.done
            ref = m.reference_decode(
                params, r.prompt, r.max_new_tokens,
                stepwise_prefill=(mode == "chunked"))
            assert r.output == ref, (mode, quant, k, r.uid,
                                     r.output, ref)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(ARCHS),
       st.sampled_from([1, 4, 8]),
       st.sampled_from(["chunked", "stall"]))
@settings(max_examples=6, deadline=None)
def test_pipelined_engine_token_identical(seed, arch, k, mode):
    """The async dimension (PR-6 tentpole): ``pipeline_depth > 1``
    keeps megasteps in flight while the host drains older blocks and
    stages admissions against a view that may lag the device by up to
    depth-1 megasteps. That staleness must move *latency only* —
    greedy token streams stay identical to the serial depth-1 engine
    across all four cache families, both admission modes, and
    K ∈ {1, 4, 8}: occupant snapshots pin each drained block to the
    requests that rode it, retired slots' frozen write masks keep
    late in-flight substeps from touching their caches, and admission
    only targets slots idle throughout every in-flight megastep."""
    cfg, m, params = _model(arch)
    rng = np.random.default_rng(seed)
    reqs_spec = [(p.prompt, p.max_new_tokens)
                 for p in _random_requests(cfg, rng,
                                           int(rng.integers(2, 6)))]
    outs = {}
    for depth in (1, 2, 3):
        eng = _engine(arch, 2, k, mode)
        eng.pipeline_depth = depth
        reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(reqs_spec)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        assert eng.in_flight == 0        # run() flushed the pipeline
        outs[depth] = [r.output for r in reqs]
    assert outs[2] == outs[1], (arch, k, mode)
    assert outs[3] == outs[1], (arch, k, mode)


@given(st.integers(0, 2 ** 31 - 1), st.floats(0.5, 2.0))
@settings(max_examples=5, deadline=None)
def test_greedy_slot_unaffected_by_stochastic_neighbour(seed, temp):
    """Per-slot sampling isolation: a greedy request's stream is
    identical to the single-request reference no matter what sampling
    params its batch neighbour uses (greedy rows never touch PRNG)."""
    cfg, m, params = _model("deepseek-7b")
    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
    eng = _engine("deepseek-7b", 2, 8, "chunked")
    greedy = Request(uid=0, prompt=prompt, max_new_tokens=8)
    hot = Request(uid=1, prompt=prompt, max_new_tokens=8,
                  temperature=float(temp), top_k=40)
    eng.submit(greedy)
    eng.submit(hot)
    eng.run()
    assert greedy.done and hot.done and len(hot.output) == 8
    assert greedy.output == m.reference_decode(params, prompt, 8)


PAGE_SIZES = (8, 16, 32)          # all divide the 64-slot cache ring


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(ARCHS),
       st.sampled_from([1, 4, 8]),
       st.sampled_from(["chunked", "stall"]))
@settings(max_examples=6, deadline=None)
def test_paged_engine_token_identical(seed, arch, k, mode):
    """The paging dimension (PR-9 tentpole): a paged engine — block
    pool + slot->block-table indirection, allocator recycling on
    retirement — must be greedy token-identical to the dense engine
    for every page size, across all four cache families, both
    admission modes and megastep K ∈ {1, 4, 8}. For the recurrent /
    windowed families paging is a structural no-op
    (``Model.paging_effective``) and the identity holds trivially
    through the dense fallback; for full attention it pins the
    gather/scatter-through-table read and write paths, the frozen
    garbage-block writes of retired slots, and the admission-time
    table splice."""
    cfg, m, params = _model(arch)
    rng = np.random.default_rng(seed)
    reqs_spec = [(p.prompt, p.max_new_tokens)
                 for p in _random_requests(cfg, rng,
                                           int(rng.integers(2, 6)))]

    def run(page):
        eng = _engine(arch, 2, k, mode, page=page)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(reqs_spec)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        if eng.paged:
            assert eng.blocks_in_use == 0   # allocator fully recycled
        return [r.output for r in reqs]

    dense = run(0)
    for page in PAGE_SIZES:
        assert run(page) == dense, (arch, k, mode, page)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(QUANTS),
       st.sampled_from([1, 4, 8]))
@settings(max_examples=3, deadline=None)
def test_paged_quantized_cache_token_identical(seed, kv, k):
    """Paging composes with PR-4's quantized cache leaves: int8
    payload + groupwise scale pages ride the same block tables, and
    the paged engine stays token-identical to the dense engine under
    the same ``cfg.kv_quant``."""
    cfg, m, params = _model("deepseek-7b", kv=kv)
    rng = np.random.default_rng(seed)
    reqs_spec = [(p.prompt, p.max_new_tokens)
                 for p in _random_requests(cfg, rng, 3)]
    outs = {}
    for page in (0, 8):
        eng = _engine("deepseek-7b", 2, k, "chunked", kv=kv, page=page)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(reqs_spec)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        outs[page] = [r.output for r in reqs]
    assert outs[8] == outs[0], (kv, k)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 4, 8]),
       st.sampled_from(PAGE_SIZES))
@settings(max_examples=4, deadline=None)
def test_prefix_cache_token_identical_hits_and_misses(seed, k, page):
    """Shared-prefix copy-on-write reuse: a prefix-cache engine
    serving a mix of shared-prefix requests (hits after the first
    registration) and unrelated prompts (misses) emits exactly the
    dense engine's greedy tokens — the cached pages hold the same
    bytes chunked admission would have written, so skipping their
    rider substeps can't move a token. Hit accounting must light up
    and every block must recycle once the queue drains."""
    cfg, m, params = _model("deepseek-7b")
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size,
                          size=int(page * 2 + 1)).astype(np.int32)
    spec = []
    for i in range(5):
        tail = rng.integers(1, cfg.vocab_size, size=int(
            rng.integers(1, 6))).astype(np.int32)
        if i % 2 == 0:      # shared-prefix requests interleaved with
            prompt = np.concatenate([prefix, tail])
        else:               # unrelated prompts (misses)
            prompt = tail
        spec.append((prompt, int(rng.integers(1, 6))))

    def run(pg, pfx):
        eng = _engine("deepseek-7b", 2, k, "chunked", page=pg,
                      prefix=pfx)
        hits0 = eng.stats.prefix_hits
        reqs = [Request(uid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(spec)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return [r.output for r in reqs], eng.stats.prefix_hits - hits0, \
            eng

    dense, _, _ = run(0, False)
    paged, hits, eng = run(page, True)
    assert paged == dense, (k, page)
    assert hits >= 1, "shared-prefix workload produced no cache hits"
    # after the queue drains, only the registry's own references
    # remain — every slot-held block recycled
    assert eng.blocks_in_use == len(eng._prefix_reg)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_sample_batched_greedy_rows_are_argmax(seed):
    """sampler invariants: temperature<=0 rows are exact argmax; with
    uniform per-row params the batched sampler draws the same tokens
    as the static-config path."""
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (4, 32)) * 3.0
    B = logits.shape[0]
    greedy = sample_batched(
        logits, key, jnp.zeros((B,)), jnp.zeros((B,), jnp.int32),
        jnp.ones((B,)))
    assert greedy.tolist() == jnp.argmax(logits, -1).tolist()
    cfg = SamplingConfig(temperature=0.7, top_k=5, top_p=0.9)
    static = sample(logits, key, cfg)
    batched = sample_batched(
        logits, key, jnp.full((B,), cfg.temperature),
        jnp.full((B,), cfg.top_k, jnp.int32), jnp.full((B,), cfg.top_p))
    assert static.tolist() == batched.tolist()
