"""Dry-run launcher: the multi-pod compile proof, exercised in CI on a
representative subset (the full 40-combo x 2-mesh sweep runs via
``python -m repro.launch.dryrun --all [--multi-pod]``; its results are
checked into results/*.json).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_dryrun(*args):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args,
         "--no-calibrate"],
        capture_output=True, text=True, env=env, timeout=580)


@pytest.mark.parametrize("arch,shape", [
    ("deepseek-7b", "decode_32k"),        # dense decode, 2TB MHA cache
    ("mamba2-2.7b", "long_500k"),         # attention-free long context
])
def test_single_pod_dryrun_compiles(arch, shape):
    proc = _run_dryrun("--arch", arch, "--shape", shape)
    assert "1/1 combos compiled OK" in proc.stdout, proc.stderr[-2000:]


def test_multi_pod_dryrun_compiles():
    proc = _run_dryrun("--arch", "recurrentgemma-2b", "--shape",
                       "decode_32k", "--multi-pod")
    assert "1/1 combos compiled OK" in proc.stdout, proc.stderr[-2000:]


@pytest.mark.parametrize("fname,chips", [
    ("dryrun_single_pod.json", 256),
    ("dryrun_multi_pod.json", 512),
])
def test_sweep_results_if_present(fname, chips):
    """When the checked-in sweep results exist, every combo must be ok
    and the roofline terms populated."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        fname)
    if not os.path.exists(path):
        pytest.skip("sweep results not generated yet")
    with open(path) as f:
        results = json.load(f)
    assert len(results) == 40
    bad = [r for r in results if not r.get("ok")]
    assert not bad, [(r["arch"], r["shape"]) for r in bad]
    for r in results:
        assert r["chips"] == chips
        t = r["roofline"]
        assert t["compute_s"] > 0 and t["memory_s"] > 0
        assert t["dominant"] in ("compute", "memory", "collective")


def test_hillclimb_results_if_present():
    """The §Perf log: the headline confirmed/refuted results hold."""
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "hillclimb.json")
    if not os.path.exists(path):
        pytest.skip("hillclimb not run yet")
    rs = {r["experiment"]: r for r in json.load(open(path))
          if r.get("ok")}
    base = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_single_pod.json")
    baselines = {(r["arch"], r["shape"]): r for r in
                 json.load(open(base)) if r.get("ok")}
    qwen_base = baselines[("qwen1.5-110b", "decode_32k")]["roofline"]
    if "qwen_decode_tp1d_q4" in rs:
        opt = rs["qwen_decode_tp1d_q4"]["roofline"]
        # confirmed: collective term collapsed >= 50x
        assert opt["collective_s"] < qwen_base["collective_s"] / 50
        # and the step as a whole improved
        assert max(opt.values() if False else
                   [opt["compute_s"], opt["memory_s"],
                    opt["collective_s"]]) < \
            max(qwen_base["compute_s"], qwen_base["memory_s"],
                qwen_base["collective_s"]) / 2
    if "qwen_decode_v3_regression" in rs:
        v3 = rs["qwen_decode_v3_regression"]["roofline"]
        # the paper's V3 cliff, structurally: collectives blow up
        assert v3["collective_s"] > 3 * qwen_base["collective_s"]
