"""Sharding rules + models under a real (4-device) mesh.

These tests re-exec a small script with XLA_FLAGS so they get multiple
host devices without polluting the main test process.
"""
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    RULES_V0, RULES_V2, RULES_V3, logical_to_spec, sanitize_spec)


def test_logical_to_spec_basic():
    assert logical_to_spec(("embed", "mlp"), RULES_V2) == \
        P("data", "model")
    assert logical_to_spec(("batch", "seq", None), RULES_V2) == \
        P(("pod", "data"), "model")


def test_duplicate_mesh_axis_dropped():
    # seq and heads both map to model in v2; second use must drop
    spec = logical_to_spec(("seq", "heads"), RULES_V2)
    assert spec == P("model")


def test_v0_has_no_tensor_parallelism():
    assert logical_to_spec(("embed", "mlp"), RULES_V0) == P("data")


def test_v3_conflicts_by_design():
    # v3: attention on model, ffn on data — the paper's regression case
    assert logical_to_spec(("embed", "mlp"), RULES_V3) == P(None, "data")
    assert logical_to_spec((None, "heads"), RULES_V3) == P(None, "model")


def test_sanitize_spec_drops_nondividing():
    import jax
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)
    spec = sanitize_spec((50280, 2560), P("model", "data"), FakeMesh)
    assert spec == P(None, "data")   # 50280 % 16 != 0, 2560 % 16 == 0


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config, reduced
    from repro.distributed import context as dctx
    from repro.distributed.sharding import rules_for, tree_shardings
    from repro.models import Model
    from repro.models.params import param_pspecs

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    rules = rules_for("v2")
    cfg = reduced(get_config("{arch}"), num_kv_heads=2)
    model = Model(cfg)

    with dctx.use_mesh(mesh), dctx.use_rules(rules):
        params = model.init(jax.random.PRNGKey(0))
        specs = model.param_specs()
        shardings = tree_shardings(model.abstract_params(),
                                   param_pspecs(specs, rules, mesh), mesh)
        params = jax.device_put(params, shardings)
        B, S = 4, 16
        batch = {{"tokens": jnp.zeros((B, S), jnp.int32)}}
        logits, aux = jax.jit(model.forward)(params, batch)
        # distributed == single-device result
        params_local = jax.device_put(
            params, jax.devices()[0])
        with dctx.use_mesh(None):
            ref, _ = jax.jit(model.forward)(params_local, batch)
        a = np.asarray(logits, np.float32)
        b = np.asarray(ref, np.float32)
        err = np.abs(a - b).max() / (np.abs(b).max() + 1e-6)
        assert err < 2e-2, err
        print("SHARDED_OK", err)
""")


@pytest.mark.parametrize("arch", ["deepseek-7b", "phi3.5-moe-42b-a6.6b"])
def test_sharded_forward_matches_single_device(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, env=env, timeout=600)
    assert "SHARDED_OK" in proc.stdout, proc.stderr[-2000:]
