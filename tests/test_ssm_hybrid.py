"""SSD + RG-LRU recurrence correctness vs sequential oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ref import rglru_ref, ssd_scan_ref
from repro.models.hybrid import rglru_scan
from repro.models.ssm import ssd_chunked


def _ssd_inputs(seed, B=2, S=64, H=4, P=16, N=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    x, dt, A, Bm, Cm = _ssd_inputs(0)
    y_ref, S_ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    y, Sf = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(Sf), np.asarray(S_ref),
                               atol=2e-4)


def test_ssd_state_carry_across_calls():
    """Two chunked calls with carried state == one long call."""
    x, dt, A, Bm, Cm = _ssd_inputs(1, S=64)
    y_full, S_full = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y1, s1 = ssd_chunked(x[:, :32], dt[:, :32], A, Bm[:, :32],
                         Cm[:, :32], 16)
    y2, s2 = ssd_chunked(x[:, 32:], dt[:, 32:], A, Bm[:, 32:],
                         Cm[:, 32:], 16, init_state=s1)
    np.testing.assert_allclose(np.asarray(y2),
                               np.asarray(y_full[:, 32:]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(S_full),
                               atol=2e-4)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_decay_bounded(seed):
    """Property: with zero input the state decays monotonically (A<0)."""
    x, dt, A, Bm, Cm = _ssd_inputs(seed % 1000, S=32)
    S0 = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed % 97),
                                   (2, 4, 16, 8)))
    _, S_end = ssd_chunked(jnp.zeros_like(x), dt, A, Bm, Cm, 16,
                           init_state=S0)
    assert (np.abs(np.asarray(S_end)) <= np.asarray(S0) + 1e-5).all()


def test_rglru_matches_oracle():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, S, W = 2, 48, 16
    x = jax.random.normal(ks[0], (B, S, W))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W)))
    g = jax.nn.sigmoid(jax.random.normal(ks[2], (B, S, W)))
    y_ref, h_ref = rglru_ref(x, a, g)
    h = rglru_scan(x * g, a)
    np.testing.assert_allclose(np.asarray(h),
                               np.asarray(y_ref, np.float32), atol=1e-5)


def test_rglru_state_carry():
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, S, W = 2, 32, 8
    x = jax.random.normal(ks[0], (B, S, W))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, S, W)))
    h_full = rglru_scan(x, a)
    h1 = rglru_scan(x[:, :16], a[:, :16])
    h2 = rglru_scan(x[:, 16:], a[:, 16:], init_state=h1[:, -1])
    np.testing.assert_allclose(np.asarray(h2),
                               np.asarray(h_full[:, 16:]), atol=1e-5)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_rglru_stability(seed):
    """Property: |h| stays bounded — a in (0,1), input scaled by
    sqrt(1-a^2) keeps the recurrence contractive for bounded input."""
    ks = jax.random.split(jax.random.PRNGKey(seed % 1009), 2)
    x = jnp.clip(jax.random.normal(ks[0], (1, 256, 8)), -3, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (1, 256, 8)))
    h = rglru_scan(x, a)
    assert np.abs(np.asarray(h)).max() < 10.0
