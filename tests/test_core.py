"""Core layer: graph IR, scheduler, cost model vs the paper's numbers."""
import dataclasses

import pytest

from repro.configs.paper_models import LLAMA32_1B, QWEN2_0_5B
from repro.core import (
    Op, a17_cpu, backend_throughput, build_decoder_graph,
    find_concurrent_gemms, fusion_plan, model_flops, plan, profile_phases,
    roofline, simulate_version,
)
from repro.configs import INPUT_SHAPES, get_config


# ---------------------------------------------------------------------------
# Graph construction (paper §3, Algorithm 1)
# ---------------------------------------------------------------------------

def test_seven_weight_matmuls_per_layer():
    """Paper §6.2: 7 named weight GEMMs per decoder layer: Q, K, V,
    kqv_out, ffn_gate, ffn_up, ffn_down."""
    g = build_decoder_graph(LLAMA32_1B, seq=1, kv_len=64, fused=False)
    tags = g.matmuls_by_tag()
    for t in ("Qcur", "Kcur", "Vcur", "kqv_out", "ffn_gate", "ffn_up",
              "ffn_down"):
        assert len(tags[t]) == LLAMA32_1B.num_layers, t


def test_fusion_reduces_node_count():
    g0 = build_decoder_graph(LLAMA32_1B, seq=1, kv_len=64, fused=False)
    g1 = build_decoder_graph(LLAMA32_1B, seq=1, kv_len=64, fused=True)
    # fusing {Q,K,V}->1 and {gate,up}->1 saves 3 nodes/layer
    assert len(g0) - len(g1) == 3 * LLAMA32_1B.num_layers


def test_graph_flops_match_6nd():
    """Decode FLOPs/token ≈ 2·N_params (plus attention)."""
    g = build_decoder_graph(LLAMA32_1B, seq=1, kv_len=0, fused=False)
    n = LLAMA32_1B.param_count()
    mm = sum(nd.flops for nd in g.nodes if nd.op is Op.MUL_MAT
             and nd.weight_bytes)
    assert 0.8 < mm / (2 * n) < 1.2


# ---------------------------------------------------------------------------
# Scheduler (paper §7)
# ---------------------------------------------------------------------------

def test_concurrent_sets_found():
    """The paper's Fig 7 coloring: {Q,K,V} and {gate,up} are
    independent GEMM sets within each layer."""
    g = build_decoder_graph(LLAMA32_1B, seq=1, kv_len=64, fused=False)
    sets = find_concurrent_gemms(g)
    attn_sets = [s for s in sets if s.block == "attn"]
    ffn_sets = [s for s in sets if s.block == "ffn"]
    assert len(attn_sets) == LLAMA32_1B.num_layers
    assert all(len(s.node_ids) == 3 for s in attn_sets)     # Q, K, V
    assert len(ffn_sets) == LLAMA32_1B.num_layers
    assert all(len(s.node_ids) == 2 for s in ffn_sets)      # gate, up
    fp = fusion_plan(g)
    assert fp.fuse_qkv and fp.fuse_gate_up
    assert fp.nodes_saved == 3 * LLAMA32_1B.num_layers


def test_version_ladder_matches_paper():
    """Paper Figs 8-10: 11.5 → 13 → 15 → 6 tk/s (±10%)."""
    targets = {"v0": 11.5, "v1": 13.0, "v2": 15.0, "v3": 6.0}
    for v, want in targets.items():
        got = simulate_version(LLAMA32_1B, v, threads=4,
                               kv_len=64).tokens_per_s
        assert abs(got - want) / want < 0.10, (v, got, want)


def test_ladder_ordering():
    r = {v: simulate_version(LLAMA32_1B, v, threads=4).tokens_per_s
         for v in ("v0", "v1", "v2", "v3")}
    assert r["v0"] < r["v1"] < r["v2"]       # graph-parallel then tensor
    assert r["v3"] < r["v0"]                 # heterogeneous regression


# ---------------------------------------------------------------------------
# Fig 4 headline numbers
# ---------------------------------------------------------------------------

def test_cpu_beats_gpu_for_1b_f16():
    """Paper abstract: 2-thread CPU 17 tk/s vs GPU 12.8 tk/s."""
    cpu = backend_throughput(LLAMA32_1B, "cpu", threads=2)
    gpu = backend_throughput(LLAMA32_1B, "gpu")
    assert abs(cpu - 17.0) / 17.0 < 0.10, cpu
    assert abs(gpu - 12.8) / 12.8 < 0.10, gpu
    assert cpu > gpu


def test_gpu_wins_for_large_models():
    """Paper §5: beyond ~1.5B the GPU regains the lead (Q4, many-thread
    CPU still behind)."""
    from repro.configs.paper_models import MISTRAL_7B
    cpu = backend_throughput(MISTRAL_7B, "cpu", threads=6,
                             weight_format="q4_0")
    gpu = backend_throughput(MISTRAL_7B, "gpu", weight_format="q4_0")
    assert gpu > cpu


def test_thread_scaling_law():
    """Paper C5: throughput peaks near the P-core count and degrades
    with oversubscription."""
    tps = [backend_throughput(QWEN2_0_5B, "cpu", threads=t)
           for t in (1, 2, 4, 8, 12)]
    assert tps[1] > tps[0]                  # 2 threads beat 1
    assert tps[-1] < max(tps)               # oversubscription hurts
    assert max(tps) == max(tps[1], tps[2])  # peak at 2-4 threads


def test_q4_speedup():
    """Paper §5.3: Q4 gives 1.5-2.5x over F16."""
    f16 = backend_throughput(LLAMA32_1B, "cpu", threads=4,
                             weight_format="f16")
    q4 = backend_throughput(LLAMA32_1B, "cpu", threads=4,
                            weight_format="q4_0")
    assert 1.5 < q4 / f16 < 3.0


# ---------------------------------------------------------------------------
# Profiler (paper §6, Figs 5/6)
# ---------------------------------------------------------------------------

def test_matmul_dominates():
    profs = profile_phases(LLAMA32_1B, threads=2)
    assert profs["prefill"].mul_mat_share > 0.80     # paper: 87.6%
    assert profs["decode"].mul_mat_share > 0.70      # paper: 76.2%


def test_ffn_matmuls_are_heaviest():
    """Paper Fig 6: the FFN block (up/gate/down) dominates matmul time."""
    profs = profile_phases(LLAMA32_1B, threads=2)
    for phase in profs.values():
        by = phase.by_matmul_tag
        ffn = by["ffn_up"] + by["ffn_gate"] + by["ffn_down"]
        attn = by["Qcur"] + by["Kcur"] + by["Vcur"] + by["kqv_out"]
        assert ffn > attn


# ---------------------------------------------------------------------------
# Dispatch planner + roofline plumbing
# ---------------------------------------------------------------------------

def test_planner_quantizes_decode_not_train():
    cfg = get_config("deepseek-7b")
    p_dec = plan(cfg, INPUT_SHAPES["decode_32k"])
    p_train = plan(cfg, INPUT_SHAPES["train_4k"])
    dec_prec = {d.precision for d in p_dec.decisions}
    train_prec = {d.precision for d in p_train.decisions
                  if d.tag != "lm_head"}
    assert "q4_0" in dec_prec              # decode GEMVs are memory-bound
    assert train_prec == {"bf16"}          # train GEMMs are MXU-bound


def test_roofline_terms():
    t = roofline(hlo_flops=1e12, hlo_bytes=1e11, collective_bytes=1e9,
                 chips=256)
    assert t.compute_s == pytest.approx(1e12 / 197e12)
    assert t.memory_s == pytest.approx(1e11 / 819e9)
    assert t.collective_s == pytest.approx(1e9 / 50e9)
    assert t.dominant == "memory"


def test_roofline_weight_format_rescales_stream():
    """§5.3 as a roofline term: the bf16 weight share of hlo_bytes
    shrinks by bits_per_weight/16 and the dequant FLOPs are charged."""
    kw = dict(hlo_flops=1e12, hlo_bytes=1e11, collective_bytes=0.0,
              chips=1)
    t16 = roofline(**kw)
    wq = 8e10  # weight share of the bytes
    t4 = roofline(**kw, weight_hlo_bytes=wq, weight_format="q4_0")
    t8 = roofline(**kw, weight_hlo_bytes=wq, weight_format="q8_0")
    # q4_0 streams 4.5/16 of the weight bytes, q8_0 8.5/16
    assert t4.hlo_bytes == pytest.approx(1e11 - wq * (1 - 4.5 / 16))
    assert t8.hlo_bytes == pytest.approx(1e11 - wq * (1 - 8.5 / 16))
    assert t4.memory_s < t8.memory_s < t16.memory_s
    # dequant tax: extra flops per weight (weights = wq / 2 bytes)
    assert t4.hlo_flops == pytest.approx(1e12 + 4.0 * wq / 2)
    # bf16/f16 formats are the identity
    tid = roofline(**kw, weight_hlo_bytes=wq, weight_format="bf16")
    assert tid.memory_s == t16.memory_s and tid.hlo_flops == t16.hlo_flops


def test_simulate_precision_and_quantized_per_token():
    """Analytic precision sweep: the weight stream shrinks with
    bits-per-weight, and the dequant tax can hand the ordering back
    (the paper's Fig 4e erosion) — both visible through the model."""
    from repro.core import (a17_cpu, quantized_per_token_s,
                            simulate_precision)
    from repro.configs.paper_models import PAPER_MODELS
    hw = a17_cpu(2)
    llama = PAPER_MODELS["llama3.2-1b"]
    sim = simulate_precision(llama, hw, ks=(1, 8))
    assert set(sim) == {"f16", "q8_0", "q4_0"}
    # quantization always beats f16 on this memory-bound decode
    for fmt in ("q8_0", "q4_0"):
        assert sim[fmt][8].tokens_per_s > sim["f16"][8].tokens_per_s
    # pure stream term (no dequant): monotone in bits-per-weight
    free_flops = dataclasses.replace(hw, peak_flops=1e18)
    t16 = quantized_per_token_s(1e-3, free_flops, 2e7, "bf16")
    t8 = quantized_per_token_s(1e-3, free_flops, 2e7, "q8_0")
    t4 = quantized_per_token_s(1e-3, free_flops, 2e7, "q4_0")
    assert t4 < t8 < t16 == 1e-3
    # the dequant tax is charged at the hardware's flop rate
    assert quantized_per_token_s(1e-3, hw, 2e7, "q4_0") > t4
