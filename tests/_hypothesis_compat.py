"""Gate for the optional ``hypothesis`` dependency.

Two modes:

- hypothesis installed → re-export the real ``given``/``settings``/
  ``st`` and load a deterministic CI profile (``derandomize=True``, no
  deadline) so property tests produce the same examples on every run.
- hypothesis missing (this CI container) → a **deterministic fallback
  runner**: a minimal strategy set driven by a seeded ``random.Random``
  draws ``max_examples`` example tuples and calls the test body with
  each. Property tests therefore still *run* (not skip), with a fixed,
  reproducible example stream.

Knobs (``scripts/run_tier1.sh`` pins them):

- ``REPRO_HYP_SEED``      — fallback RNG seed (default 0; the real
  hypothesis gets determinism from ``derandomize`` instead)
- ``REPRO_HYP_EXAMPLES``  — cap on examples per test. The fallback
  applies it per test (min with the test's ``max_examples``); with
  hypothesis installed it becomes the profile default, which explicit
  per-test ``@settings(max_examples=...)`` still override.

Only the subset of the hypothesis API this repo uses is shimmed:
positional ``@given(st.integers(...), st.sampled_from(...), ...)``
above ``@settings(max_examples=..., deadline=...)``, with strategies
``integers`` / ``sampled_from`` / ``booleans`` / ``floats`` /
``lists`` / ``tuples`` / ``just``.
"""
import os

_DEF_EXAMPLES = 20


def _env_examples(default):
    cap = os.environ.get("REPRO_HYP_EXAMPLES")
    return min(default, int(cap)) if cap else default


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    _profile = dict(
        derandomize=True,
        deadline=None,
        suppress_health_check=list(HealthCheck))
    if os.environ.get("REPRO_HYP_EXAMPLES"):
        _profile["max_examples"] = int(os.environ["REPRO_HYP_EXAMPLES"])
    settings.register_profile("repro_ci", **_profile)
    settings.load_profile("repro_ci")
except ImportError:                     # pragma: no cover
    import random

    class _Strategy:
        def example(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.randint(self.lo, self.hi)   # inclusive, like st

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return rng.uniform(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, items):
            self.items = list(items)

        def example(self, rng):
            return rng.choice(self.items)

    class _Booleans(_Strategy):
        def example(self, rng):
            return rng.random() < 0.5

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=10):
            self.elem, self.lo, self.hi = elem, min_size, max_size

        def example(self, rng):
            n = rng.randint(self.lo, self.hi)
            return [self.elem.example(rng) for _ in range(n)]

    class _Tuples(_Strategy):
        def __init__(self, *elems):
            self.elems = elems

        def example(self, rng):
            return tuple(e.example(rng) for e in self.elems)

    class _Just(_Strategy):
        def __init__(self, value):
            self.value = value

        def example(self, rng):
            return self.value

    class _St:
        @staticmethod
        def integers(min_value=0, max_value=2 ** 31 - 1):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(items):
            return _SampledFrom(items)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def lists(elem, min_size=0, max_size=10, **_kw):
            return _Lists(elem, min_size, max_size)

        @staticmethod
        def tuples(*elems):
            return _Tuples(*elems)

        @staticmethod
        def just(value):
            return _Just(value)

    st = _St()

    def settings(max_examples=_DEF_EXAMPLES, **_kwargs):
        def deco(fn):
            fn._repro_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper():
                n = _env_examples(
                    getattr(fn, "_repro_max_examples", _DEF_EXAMPLES))
                seed = int(os.environ.get("REPRO_HYP_SEED", "0"))
                rng = random.Random(seed)
                for i in range(n):
                    args = [s.example(rng) for s in strategies]
                    try:
                        fn(*args)
                    except Exception:
                        print(f"[hypothesis-compat] falsifying example "
                              f"#{i} (seed={seed}): {args!r}")
                        raise
            # zero-arg wrapper: the original signature only names
            # generated params, which pytest would otherwise try to
            # resolve as fixtures
            wrapper.__name__ = getattr(fn, "__name__", "property_test")
            wrapper.__doc__ = getattr(fn, "__doc__", None)
            return wrapper
        return deco

__all__ = ["given", "settings", "st"]
