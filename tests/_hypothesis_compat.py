"""Gate for the optional ``hypothesis`` dependency.

The container may not ship hypothesis; property-based tests then skip
individually while the example-based tests in the same module still
run (a bare ``import hypothesis`` at module top would error the whole
collection instead).
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover
    import pytest

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg wrapper: the original signature only names
            # hypothesis-generated params, which pytest would otherwise
            # try to resolve as fixtures
            def wrapper():
                pytest.skip("hypothesis not installed")
            wrapper.__name__ = getattr(fn, "__name__", "property_test")
            return wrapper
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["given", "settings", "st"]
