import os

# Keep smoke tests on the single real device (the dry-run sets its own
# device count in its own process). Pallas kernels run in interpret mode.
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import jax

jax.config.update("jax_enable_x64", False)
