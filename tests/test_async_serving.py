"""Async front-end + admission edge-case suite (PR-6 satellites).

Covers the pieces the property suite's token-identity pin doesn't:

- ``ServingEngine.submit`` admission edge cases: empty prompt and
  negative budget rejected at submit time, ``max_new_tokens=0``
  short-circuits to a completed empty output — each held to
  ``Model.reference_decode`` where a reference exists.
- ``ServingEngine.cancel`` at every lifecycle stage (queued,
  mid-prefill, mid-decode), including that a cancellation leaves the
  engine healthy: a request submitted *after* the cancel still matches
  the single-request reference (the frozen-write retirement path left
  no cache corruption behind).
- ``AsyncServingFrontend``: streaming callbacks, deadline expiry
  (``DeadlineExceeded`` carrying partial tokens, ``stats.cancelled``
  incremented), task cancellation, backpressure bound, and greedy
  results identical to the reference loop.
- ``launch.serve.build_parser``: the ``--reduced`` flag is a
  ``BooleanOptionalAction`` — reduced by default, ``--no-reduced``
  selects the paper-size model (the PR-6 bugfix; the old
  ``store_true`` default-False silently ran full-size).

The async tests run coroutines with ``asyncio.run`` inside ordinary
sync test functions (no pytest-asyncio dependency). Engines are cached
module-wide and ``reset()`` between tests, same trick as the property
suite.
"""
import asyncio

import pytest

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, ServingEngine
from repro.launch.serve import (AsyncServingFrontend, DeadlineExceeded,
                                build_parser)

_CACHE = {}


def _stack(slots=2, k=4):
    key = (slots, k)
    if key not in _CACHE:
        cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                      vocab_size=256, num_heads=2, num_kv_heads=1)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ServingEngine(m, params, slots=slots, max_len=64,
                            megastep_k=k, admission="chunked",
                            prefill_chunk=16)
        _CACHE[key] = (cfg, m, params, eng)
    cfg, m, params, eng = _CACHE[key]
    eng.reset()
    eng.pipeline_depth = 1
    return cfg, m, params, eng


def _prompt(cfg, n=6, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)


# -- submit() admission edge cases ------------------------------------


def test_submit_rejects_empty_prompt():
    cfg, m, params, eng = _stack()
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=0, prompt=np.zeros(0, np.int32),
                           max_new_tokens=4))
    assert not eng.has_work()


def test_submit_rejects_negative_budget():
    cfg, m, params, eng = _stack()
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(Request(uid=0, prompt=_prompt(cfg),
                           max_new_tokens=-1))


def test_submit_zero_budget_matches_reference():
    """max_new_tokens=0 completes immediately with an empty output —
    exactly what the reference loop produces for a zero budget — and
    never occupies a slot (an admitted zero-budget request would emit
    one token, because the in-scan retirement check runs post-emit)."""
    cfg, m, params, eng = _stack()
    p = _prompt(cfg)
    req = Request(uid=0, prompt=p, max_new_tokens=0)
    eng.submit(req)
    assert req.done and req.output == []
    assert not eng.has_work()
    assert req.output == m.reference_decode(params, p, 0)


def test_zero_budget_next_to_live_requests():
    """Zero-budget no-ops interleaved with real requests don't perturb
    the batch: the live requests still match the reference."""
    cfg, m, params, eng = _stack()
    live = [Request(uid=i, prompt=_prompt(cfg, 4 + i, seed=i),
                    max_new_tokens=6) for i in range(2)]
    noop = Request(uid=9, prompt=_prompt(cfg), max_new_tokens=0)
    eng.submit(live[0])
    eng.submit(noop)
    eng.submit(live[1])
    eng.run()
    assert noop.output == []
    for r in live:
        assert r.output == m.reference_decode(params, r.prompt,
                                              r.max_new_tokens)


def test_pipeline_depth_validated():
    cfg, m, params, eng = _stack()
    with pytest.raises(ValueError, match="pipeline_depth"):
        ServingEngine(m, params, slots=2, max_len=64, pipeline_depth=0)


# -- cancel() across the request lifecycle ----------------------------


def test_cancel_queued_request():
    cfg, m, params, eng = _stack(slots=1)
    a = Request(uid=0, prompt=_prompt(cfg), max_new_tokens=4)
    b = Request(uid=1, prompt=_prompt(cfg, seed=1), max_new_tokens=4)
    eng.submit(a)
    eng.submit(b)                      # queued behind a on the 1 slot
    assert eng.cancel(b)
    assert b.cancelled and b.done and b.output == []
    eng.run()
    assert a.output == m.reference_decode(params, a.prompt, 4)
    assert eng.stats.cancelled == 1


def test_cancel_mid_prefill():
    """Cancel while the slot is still consuming prompt tokens (long
    prompt, K=1 so one step admits at most a few chunk tokens). The
    retired slot frees immediately and the neighbour is unharmed."""
    cfg, m, params, eng = _stack(slots=2, k=1)
    long_p = _prompt(cfg, n=40, seed=2)
    victim = Request(uid=0, prompt=long_p, max_new_tokens=8)
    other = Request(uid=1, prompt=_prompt(cfg, seed=3), max_new_tokens=8)
    eng.submit(victim)
    eng.submit(other)
    eng.step()                         # victim is mid-prefill now
    assert not victim.done
    assert eng.cancel(victim)
    assert victim.cancelled and victim.output == []
    eng.run()
    assert other.output == m.reference_decode(params, other.prompt, 8)
    # the freed slot admits and serves a fresh request correctly
    late = Request(uid=2, prompt=_prompt(cfg, seed=4), max_new_tokens=6)
    eng.submit(late)
    eng.run()
    assert late.output == m.reference_decode(params, late.prompt, 6)


def test_cancel_mid_decode_keeps_partial_output():
    cfg, m, params, eng = _stack(slots=1, k=4)
    req = Request(uid=0, prompt=_prompt(cfg), max_new_tokens=32)
    eng.submit(req)
    eng.step()                         # prefill + first decode tokens
    while not req.output and not req.done:
        eng.step()
    got = list(req.output)
    assert 0 < len(got) < 32
    assert eng.cancel(req)
    assert req.done and req.output == got      # partial stream kept
    # partial tokens are a prefix of the reference stream
    ref = m.reference_decode(params, req.prompt, 32)
    assert got == ref[:len(got)]
    assert not eng.has_work()
    # cancel of a finished request is a no-op
    assert not eng.cancel(req)
    assert eng.stats.cancelled == 1


def test_cancel_during_inflight_megastep_pipelined():
    """Cancellation composes with pipelining: retire a slot while a
    dispatched megastep is still in flight — late tokens from that
    megastep must be dropped, and the stream stays a reference
    prefix."""
    cfg, m, params, eng = _stack(slots=2, k=4)
    eng.pipeline_depth = 2
    req = Request(uid=0, prompt=_prompt(cfg), max_new_tokens=32)
    eng.submit(req)
    eng.step()                         # dispatches ahead of the drain
    while not req.output and not req.done:
        eng.step()
    got = list(req.output)
    assert eng.cancel(req)
    eng.run()                          # flush the in-flight megastep
    assert eng.in_flight == 0
    assert req.output == got           # no late tokens leaked in
    ref = m.reference_decode(params, req.prompt, 32)
    assert req.output == ref[:len(got)]


# -- AsyncServingFrontend ---------------------------------------------


def test_frontend_streams_and_matches_reference():
    cfg, m, params, eng = _stack()
    prompts = [_prompt(cfg, 4 + i, seed=10 + i) for i in range(5)]
    streamed = {i: [] for i in range(5)}

    async def drive():
        fe = AsyncServingFrontend(eng, max_pending=3)
        outs = await asyncio.gather(*[
            fe.generate(p, max_new_tokens=6,
                        on_token=streamed[i].append)
            for i, p in enumerate(prompts)])
        await fe.close()
        return outs

    outs = asyncio.run(drive())
    for i, p in enumerate(prompts):
        ref = m.reference_decode(params, p, 6)
        assert outs[i] == ref
        assert streamed[i] == ref      # callback saw every token once


def test_frontend_backpressure_bound():
    """With max_pending=2 the engine never holds more than 2 admitted-
    but-unfinished requests, however many generate() calls are made."""
    cfg, m, params, eng = _stack(slots=2)
    high_water = 0

    async def drive():
        nonlocal high_water
        fe = AsyncServingFrontend(eng, max_pending=2)

        def watch(_tok, fe=fe):
            nonlocal high_water
            high_water = max(high_water,
                             fe.max_pending - fe._sem._value)

        outs = await asyncio.gather(*[
            fe.generate(_prompt(cfg, seed=20 + i), max_new_tokens=4,
                        on_token=watch)
            for i in range(6)])
        await fe.close()
        return outs

    outs = asyncio.run(drive())
    assert len(outs) == 6 and all(len(o) == 4 for o in outs)
    assert high_water <= 2

    with pytest.raises(ValueError, match="max_pending"):
        AsyncServingFrontend(eng, max_pending=0)


def test_frontend_deadline_expiry_retires_and_recovers():
    """A request with an impossible deadline raises DeadlineExceeded
    (partial tokens attached), increments the engine's cancel counter,
    and leaves the engine serving correct tokens afterwards."""
    cfg, m, params, eng = _stack(slots=1, k=1)
    p = _prompt(cfg, n=12, seed=30)
    base = eng.stats.cancelled

    async def drive():
        fe = AsyncServingFrontend(eng)
        try:
            await fe.generate(p, max_new_tokens=500, deadline_s=0.0)
        except DeadlineExceeded as e:
            err = e
        else:
            err = None
        # engine must still be healthy: fresh request completes
        ok = await fe.generate(p, max_new_tokens=5)
        await fe.close()
        return err, ok

    err, ok = asyncio.run(drive())
    assert err is not None
    assert err.tokens == []            # deadline hit before admission
    assert eng.stats.cancelled == base + 1
    assert ok == m.reference_decode(params, p, 5)


def test_frontend_propagates_submit_rejection():
    cfg, m, params, eng = _stack()

    async def drive():
        fe = AsyncServingFrontend(eng)
        with pytest.raises(ValueError, match="empty prompt"):
            await fe.generate(np.zeros(0, np.int32), max_new_tokens=4)
        toks = await fe.generate(_prompt(cfg), max_new_tokens=0)
        await fe.close()
        return toks

    assert asyncio.run(drive()) == []


def test_frontend_task_cancellation_cancels_request():
    """Cancelling the awaiting asyncio task retires the request in the
    engine (the staged-cancel path), and the loop keeps serving."""
    cfg, m, params, eng = _stack(slots=1, k=1)
    base = eng.stats.cancelled

    async def drive():
        fe = AsyncServingFrontend(eng)
        victim = asyncio.ensure_future(
            fe.generate(_prompt(cfg, n=20, seed=40),
                        max_new_tokens=500))
        await asyncio.sleep(0.05)      # let it admit and start
        victim.cancel()
        try:
            await victim
        except asyncio.CancelledError:
            pass
        survivor = await fe.generate(_prompt(cfg, seed=41),
                                     max_new_tokens=5)
        await fe.close()
        return survivor

    survivor = asyncio.run(drive())
    assert eng.stats.cancelled >= base + 1
    ref = m.reference_decode(params, _prompt(cfg, seed=41), 5)
    assert survivor == ref


# -- CLI flag parsing (the --reduced bugfix) --------------------------


def test_reduced_flag_default_and_both_branches():
    ap = build_parser()
    assert ap.parse_args([]).reduced is True           # safe default
    assert ap.parse_args(["--reduced"]).reduced is True
    assert ap.parse_args(["--no-reduced"]).reduced is False


def test_parser_async_knobs():
    ap = build_parser()
    args = ap.parse_args(["--pipeline-depth", "2", "--frontend",
                          "--deadline-s", "0.5"])
    assert args.pipeline_depth == 2 and args.frontend
    assert args.deadline_s == 0.5
    defaults = ap.parse_args([])
    assert defaults.pipeline_depth == 1 and not defaults.frontend
    assert defaults.deadline_s is None


# -- cancel() x paged prefix sharing (PR-9) ---------------------------


def test_cancel_idempotent_and_completed_noop():
    """cancel() at every terminal state: the second cancel of a
    cancelled request and the cancel of a normally completed request
    both return False and don't bump stats.cancelled again."""
    cfg, m, params, eng = _stack()
    victim = Request(uid=0, prompt=_prompt(cfg), max_new_tokens=8)
    eng.submit(victim)
    eng.step()
    assert eng.cancel(victim)
    assert not eng.cancel(victim)          # double-cancel: no-op
    assert eng.stats.cancelled == 1
    done = Request(uid=1, prompt=_prompt(cfg, seed=5), max_new_tokens=4)
    eng.submit(done)
    eng.run()
    assert done.done and not done.cancelled
    assert not eng.cancel(done)            # cancel-of-completed: no-op
    assert eng.stats.cancelled == 1


def test_cancel_keeps_shared_prefix_blocks_live():
    """Cancelling a slot whose prompt pages are shared (prefix-cache
    hit) must NOT free the shared blocks: the sibling request still
    holds references and must keep decoding correctly, and the
    registry entry survives for future admissions."""
    cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=1)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=4,
                        admission="chunked", prefill_chunk=16,
                        page_size=8, prefix_cache=True)
    shared = _prompt(cfg, n=17, seed=7)    # 2 full pages to share
    # first pass registers the prefix pages in the engine registry
    warm = Request(uid=0, prompt=shared, max_new_tokens=4)
    eng.submit(warm)
    eng.run()
    assert len(eng._prefix_reg) > 0
    reg_blocks = set(eng._prefix_reg.values())

    victim = Request(uid=1, prompt=shared, max_new_tokens=16)
    keeper = Request(uid=2, prompt=shared, max_new_tokens=16)
    eng.submit(victim)
    eng.submit(keeper)
    while not (victim.output and keeper.output):
        eng.step()
    assert eng.stats.prefix_hits >= 2      # both admissions reused pages
    before = eng.blocks_in_use
    assert eng.cancel(victim)
    # shared pages survive the cancel: refcounts dropped, not zeroed
    assert all(eng._ref[b] >= 1 for b in reg_blocks)
    assert eng.blocks_in_use < before      # victim's private tail freed
    eng.run()
    assert keeper.output == m.reference_decode(params, shared, 16)
    # registry entries keep their own reference after full drain
    assert eng.blocks_in_use == len(eng._prefix_reg) > 0
