"""Chaos property suite: seeded fault schedules against the serving
engine, across {dense, paged} x pipeline_depth {1, 2} x K {1, 8}.

Every example draws a reproducible ``FaultSchedule`` (allocator
exhaustion, forced preemption, poisoned logits, host stalls, transient
step exceptions) and replays a random request batch under it with
``engine.audit()`` asserted after *every* step. The contract:

- the audit invariants hold throughout the storm (free list ∪
  quarantine ∪ block tables partitions the pool, refcounts match
  references, block 0 stays the garbage block);
- no blocks leak — after the drain the pool is fully recoverable;
- surviving requests (not poisoned, not cancelled) finish greedy
  token-identical to ``Model.reference_decode`` — preempted-and-
  resumed ones included;
- a poisoned request error-retires with ``nonfinite-logits`` and its
  pre-poison tokens are a clean prefix of the reference stream.

Runs under ``tests/_hypothesis_compat`` (seeded, deterministic).
Marked ``chaos``; ``scripts/run_tier1.sh`` runs a separate one-shot
smoke for the exhaustion+poison+recovery path.
"""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import (FaultEvent, FaultInjector, FaultSchedule,
                           Request, ServingEngine)

pytestmark = pytest.mark.chaos

_STATE = {}


def _model():
    if "m" not in _STATE:
        cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                      vocab_size=256, num_heads=2, num_kv_heads=1)
        m = Model(cfg)
        _STATE["m"] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _STATE["m"]


def _engine(mode, k) -> ServingEngine:
    key = (mode, k)
    if key not in _STATE:
        cfg, m, params = _model()
        kw = dict(slots=3, max_len=64, megastep_k=k, prefill_chunk=16)
        if mode == "paged":
            # 12 usable blocks, <= 4 pages/request: full backing for
            # the 3 slots — contention comes from the exhaust_pool
            # fault quarantining blocks mid-flight
            kw.update(page_size=8, cache_blocks=13)
        _STATE[key] = ServingEngine(m, params, **kw)
    eng = _STATE[key]
    eng.reset()
    eng.pipeline_depth = 1
    return eng


def _random_requests(cfg, rng, n):
    return [Request(
        uid=i,
        prompt=rng.integers(1, cfg.vocab_size, size=int(
            rng.integers(2, 14))).astype(np.int32),
        max_new_tokens=int(rng.integers(1, 12)))
        for i in range(n)]


def _check_outcome(m, params, eng, reqs):
    assert not eng.has_work()
    if eng.paged:
        # pool fully recoverable: nothing quarantined, nothing leaked
        assert not eng._quarantined
        assert eng.blocks_in_use == len(eng._prefix_reg)
    for r in reqs:
        assert r.done
        ref = m.reference_decode(params, r.prompt, r.max_new_tokens)
        if r.error is not None:
            assert r.error == "nonfinite-logits"
            # pre-poison tokens are a clean prefix of the reference
            assert r.output == ref[:len(r.output)], r.uid
        else:
            assert r.output == ref, (r.uid, r.output, ref)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["dense", "paged"]),
       st.sampled_from([1, 2]), st.sampled_from([1, 8]))
@settings(max_examples=12, deadline=None)
def test_chaos_schedule_survivors_match_reference(seed, mode, depth, k):
    cfg, m, params = _model()
    rng = np.random.default_rng(seed)
    reqs = _random_requests(cfg, rng, int(rng.integers(2, 6)))
    sched = FaultSchedule.seeded(seed, n_requests=len(reqs),
                                 paged=(mode == "paged"))
    eng = _engine(mode, k)
    eng.pipeline_depth = depth
    for r in reqs:
        eng.submit(r)
    inj = FaultInjector(eng, sched, audit=True, backoff_s=0.0,
                        sleep=lambda s: None)
    inj.run(reqs)
    _check_outcome(m, params, eng, reqs)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["dense", "paged"]),
       st.sampled_from([1, 2]))
@settings(max_examples=6, deadline=None)
def test_repeated_preemption_stays_token_identical(seed, mode, depth):
    """Preempt the same request on several consecutive steps: each
    resume re-prefills prompt + generated prefix and must land on the
    uninterrupted greedy stream."""
    cfg, m, params = _model()
    rng = np.random.default_rng(seed)
    reqs = _random_requests(cfg, rng, 3)
    tgt = int(rng.integers(0, len(reqs)))
    sched = FaultSchedule([FaultEvent(s, "preempt", ridx=tgt)
                           for s in (1, 3, 5)])
    eng = _engine(mode, 8)
    eng.pipeline_depth = depth
    for r in reqs:
        eng.submit(r)
    FaultInjector(eng, sched, audit=True,
                  sleep=lambda s: None).run(reqs)
    _check_outcome(m, params, eng, reqs)
    assert all(r.error is None for r in reqs)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([1, 8]))
@settings(max_examples=6, deadline=None)
def test_pool_exhaustion_storm_recovers(seed, k):
    """Quarantine most of the pool mid-flight, repeatedly: admissions
    must block/putback (never corrupt), and the stream must complete
    token-identical once blocks return."""
    cfg, m, params = _model()
    rng = np.random.default_rng(seed)
    reqs = _random_requests(cfg, rng, 5)
    sched = FaultSchedule([
        FaultEvent(0, "exhaust_pool", blocks=int(rng.integers(6, 12)),
                   duration=2),
        FaultEvent(3, "exhaust_pool", blocks=int(rng.integers(2, 8)),
                   duration=1),
    ])
    eng = _engine("paged", k)
    for r in reqs:
        eng.submit(r)
    FaultInjector(eng, sched, audit=True,
                  sleep=lambda s: None).run(reqs)
    _check_outcome(m, params, eng, reqs)


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["dense", "paged"]),
       st.sampled_from([1, 2]))
@settings(max_examples=6, deadline=None)
def test_poison_isolates_survivors_bytewise(seed, mode, depth):
    """Run the same batch with and without one poisoned request: the
    survivors' token streams must be byte-identical — the poisoned
    slot's NaN never contaminates a co-batched request."""
    cfg, m, params = _model()
    rng = np.random.default_rng(seed)
    reqs = _random_requests(cfg, rng, 4)
    tgt = int(rng.integers(0, len(reqs)))

    # clean pass
    eng = _engine(mode, 8)
    eng.pipeline_depth = depth
    clean = [Request(uid=r.uid, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens) for r in reqs]
    for r in clean:
        eng.submit(r)
    eng.run()
    eng.audit()

    # poisoned pass, same engine (reset keeps compiled executables)
    eng.reset()
    eng.pipeline_depth = depth
    # inject before the first step: the poison sticks to the uid, so
    # it fires at whichever megastep first serves the target
    sched = FaultSchedule([FaultEvent(0, "poison_logits", ridx=tgt)])
    for r in reqs:
        eng.submit(r)
    FaultInjector(eng, sched, audit=True,
                  sleep=lambda s: None).run(reqs)
    _check_outcome(m, params, eng, reqs)
    assert reqs[tgt].error == "nonfinite-logits"
    for rc, rp in zip(clean, reqs):
        if rp.error is None:
            assert rp.output == rc.output, rp.uid
