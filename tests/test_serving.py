"""Serving engine + sampler behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, SamplingConfig, ServingEngine, sample


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced(get_config("deepseek-7b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_greedy_sampling_deterministic():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
    out = sample(logits, jax.random.PRNGKey(0), SamplingConfig())
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -50.0]])
    cfg = SamplingConfig(temperature=1.0, top_k=2)
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed), cfg)[0])
        assert t in (1, 2)


def test_engine_completes_all_requests(engine_setup):
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i + 1,
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 6 for r in reqs)
    assert eng.stats.prefills == 5


def test_engine_greedy_matches_manual_decode(engine_setup):
    """Engine output == hand-rolled prefill+decode loop (greedy)."""
    cfg, m, params = engine_setup
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServingEngine(m, params, slots=1, max_len=64)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.submit(req)
    eng.run()

    cache = m.init_cache(1, 64)
    logits, cache = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, cache = m.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0])))
    assert req.output == toks


def test_engine_eos_stops_early(engine_setup):
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=1, max_len=64)
    # discover the greedy first token, then use it as "EOS"
    probe = Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=1)
    eng.submit(probe)
    eng.run()
    eos = probe.output[0]
    eng2 = ServingEngine(m, params, slots=1, max_len=64)
    req = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32),
                  max_new_tokens=50, eos_id=eos)
    eng2.submit(req)
    eng2.run()
    assert req.done and len(req.output) == 1


def test_megastep_equivalence_greedy(engine_setup):
    """Megastep K=8 must be token-identical to K=1 greedy decode —
    including mid-block retirement (max_new=11 is not a multiple of 8)
    and slot refill (3 requests share 2 slots)."""
    cfg, m, params = engine_setup
    outs = {}
    for k in (1, 8):
        eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=k,
                            megastep_unroll=(k == 8))
        reqs = [Request(uid=i,
                        prompt=np.arange(4, dtype=np.int32) + i + 1,
                        max_new_tokens=11) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        outs[k] = [r.output for r in reqs]
    assert outs[1] == outs[8]
    # K=8 used ~8x fewer dispatches for the same tokens
    assert eng.stats.megasteps < eng.stats.steps


def test_megastep_eos_mid_block(engine_setup):
    """EOS inside a K=8 block stops the slot exactly there (the frozen
    write mask keeps the cache uncorrupted for the remaining substeps)."""
    cfg, m, params = engine_setup
    prompt = np.asarray([1, 2, 3], np.int32)
    probe = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng = ServingEngine(m, params, slots=1, max_len=64, megastep_k=1)
    eng.submit(probe)
    eng.run()
    eos = probe.output[1]                 # stops mid-first-block
    i = probe.output.index(eos)

    eng2 = ServingEngine(m, params, slots=1, max_len=64, megastep_k=8)
    req = Request(uid=1, prompt=prompt, max_new_tokens=50, eos_id=eos)
    eng2.submit(req)
    eng2.run()
    assert req.done
    assert req.output == probe.output[:i + 1]


def test_megastep_max_new_mid_block(engine_setup):
    """max_new_tokens hit inside a K=8 block retires the slot there,
    and the freed slot is refilled for the next queued request."""
    cfg, m, params = engine_setup
    ref = {}
    for k in (1, 8):
        eng = ServingEngine(m, params, slots=1, max_len=64, megastep_k=k)
        reqs = [Request(uid=i,
                        prompt=np.asarray([2, 7, 1, 8], np.int32),
                        max_new_tokens=5) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and len(r.output) == 5 for r in reqs)
        ref[k] = [r.output for r in reqs]
    assert ref[1] == ref[8]


def test_batched_prefill_one_dispatch(engine_setup):
    """Prompts landing in the same length bucket prefill several slots
    per jitted dispatch (prefill_batches < prefills)."""
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=4, max_len=64)
    for i in range(4):   # lengths 5..8 → all in the pow2-8 bucket
        eng.submit(Request(uid=i,
                           prompt=np.arange(5 + i, dtype=np.int32) + 1,
                           max_new_tokens=4))
    eng.run()
    assert eng.stats.prefills == 4
    assert eng.stats.prefill_batches == 1


def test_planner_picks_megastep_k():
    """Dispatch-overhead napkin math: K grows as the device step
    shrinks relative to the launch cost, and the analytic serving
    model predicts the amortization win."""
    from repro.core import (a17_cpu, choose_megastep_k, simulate_megastep)
    hw = a17_cpu(2)
    assert choose_megastep_k(hw, step_s=1.0) == 1       # step ≫ dispatch
    assert choose_megastep_k(hw, step_s=1e-5) > 1       # dispatch-bound
    assert choose_megastep_k(hw, step_s=0.0) == 1
    ks = (1, 4, 8, 16)
    from repro.configs.paper_models import PAPER_MODELS
    import dataclasses as dc
    fast = dc.replace(hw, dispatch_overhead_s=5e-3)     # dispatch-bound
    r = simulate_megastep(PAPER_MODELS["llama3.2-1b"], fast, ks=ks)
    tps = [r[k].tokens_per_s for k in ks]
    assert tps == sorted(tps) and tps[-1] > tps[0]


def test_sliding_window_archs_serve(engine_setup):
    """Hybrid (window) and ssm archs run the engine end-to-end."""
    for arch in ("recurrentgemma-2b", "mamba2-2.7b"):
        cfg = reduced(get_config(arch))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ServingEngine(m, params, slots=2, max_len=96)
        for i in range(3):
            eng.submit(Request(uid=i,
                               prompt=np.arange(6, dtype=np.int32) + 1,
                               max_new_tokens=5))
        eng.run()
        assert eng.stats.tokens_generated >= 15
