"""Serving engine + sampler behaviour, across every cache family.

``engine_setup`` is parametrized over the four architecture families
the engine serves — full attention (deepseek), long-context dense
(mistral-nemo), SSM (mamba2) and RG-LRU hybrid with sliding-window
local attention (recurrentgemma) — so every engine test exercises
every cache layout, not just the default arch. It is additionally
parametrized over the quant policy (paper §5.3): every family also
runs with q4_0 weights, plus one q8_0 combination, so scan-over-layers
slicing of QuantizedTensor leaves, prefill cache splicing and the
frozen-write retirement mask are all exercised quantized. Each test's
oracle (``reference_decode`` / manual loops) uses the *same* quantized
params — engine-vs-reference equivalence is exact even though the
quantized token streams differ from bf16's.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.quant import quantize_tree
from repro.serving import Request, SamplingConfig, ServingEngine, sample

ARCHS = ("deepseek-7b", "mistral-nemo-12b", "mamba2-2.7b",
         "recurrentgemma-2b")
SETUPS = ([(a, "bf16") for a in ARCHS] + [(a, "q4_0") for a in ARCHS]
          + [("deepseek-7b", "q8_0")])


@pytest.fixture(scope="module", params=SETUPS,
                ids=[f"{a}-{q}" for a, q in SETUPS])
def engine_setup(request):
    arch, quant = request.param
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    if quant != "bf16":
        params = quantize_tree(params, quant, cfg.quant_group)
    return cfg, m, params


def test_greedy_sampling_deterministic():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
    out = sample(logits, jax.random.PRNGKey(0), SamplingConfig())
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -50.0]])
    cfg = SamplingConfig(temperature=1.0, top_k=2)
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed), cfg)[0])
        assert t in (1, 2)


def test_engine_completes_all_requests(engine_setup):
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i + 1,
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 6 for r in reqs)
    assert eng.stats.prefills == 5


def test_engine_greedy_matches_manual_decode(engine_setup):
    """Stall-admission engine output == hand-rolled prefill+decode
    loop (greedy) — the fused-prefill path oracle."""
    cfg, m, params = engine_setup
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServingEngine(m, params, slots=1, max_len=64,
                        admission="stall")
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.submit(req)
    eng.run()

    cache = m.init_cache(1, 64)
    logits, cache = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, cache = m.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0])))
    assert req.output == toks


def test_chunked_engine_matches_reference(engine_setup):
    """Chunked-admission engine output == the single-request reference
    decode loop, on every cache family (the in-scan admission oracle;
    randomized sweeps live in test_serving_properties.py)."""
    cfg, m, params = engine_setup
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServingEngine(m, params, slots=2, max_len=64,
                        admission="chunked")
    req = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng.submit(req)
    eng.run()
    assert req.output == m.reference_decode(params, prompt, 6)
    assert eng.stats.prefill_batches == 0


def test_engine_eos_stops_early(engine_setup):
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=1, max_len=64)
    # discover the greedy first token, then use it as "EOS"
    probe = Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=1)
    eng.submit(probe)
    eng.run()
    eos = probe.output[0]
    eng.reset()
    req = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32),
                  max_new_tokens=50, eos_id=eos)
    eng.submit(req)
    eng.run()
    assert req.done and len(req.output) == 1


def test_megastep_equivalence_greedy(engine_setup):
    """Megastep K=8 must be token-identical to K=1 greedy decode —
    including mid-block retirement (max_new=11 is not a multiple of 8)
    and slot refill (3 requests share 2 slots)."""
    cfg, m, params = engine_setup
    outs = {}
    for k in (1, 8):
        eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=k,
                            megastep_unroll=(k == 8))
        reqs = [Request(uid=i,
                        prompt=np.arange(4, dtype=np.int32) + i + 1,
                        max_new_tokens=11) for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        outs[k] = [r.output for r in reqs]
    assert outs[1] == outs[8]
    # K=8 used ~8x fewer dispatches for the same tokens
    assert eng.stats.megasteps < eng.stats.steps


def test_megastep_eos_mid_block(engine_setup):
    """EOS inside a K=8 block stops the slot exactly there (the frozen
    write mask keeps the cache uncorrupted for the remaining substeps)."""
    cfg, m, params = engine_setup
    prompt = np.asarray([1, 2, 3], np.int32)
    probe = Request(uid=0, prompt=prompt, max_new_tokens=6)
    eng = ServingEngine(m, params, slots=1, max_len=64, megastep_k=1)
    eng.submit(probe)
    eng.run()
    eos = probe.output[1]                 # stops mid-first-block
    i = probe.output.index(eos)

    eng2 = ServingEngine(m, params, slots=1, max_len=64, megastep_k=8)
    req = Request(uid=1, prompt=prompt, max_new_tokens=50, eos_id=eos)
    eng2.submit(req)
    eng2.run()
    assert req.done
    assert req.output == probe.output[:i + 1]


def test_megastep_max_new_mid_block(engine_setup):
    """max_new_tokens hit inside a K=8 block retires the slot there,
    and the freed slot is refilled for the next queued request."""
    cfg, m, params = engine_setup
    ref = {}
    for k in (1, 8):
        eng = ServingEngine(m, params, slots=1, max_len=64, megastep_k=k)
        reqs = [Request(uid=i,
                        prompt=np.asarray([2, 7, 1, 8], np.int32),
                        max_new_tokens=5) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done and len(r.output) == 5 for r in reqs)
        ref[k] = [r.output for r in reqs]
    assert ref[1] == ref[8]


def test_per_slot_sampling_mixed_batch(engine_setup):
    """Two slots with different temperatures decode in ONE batch: the
    greedy slot's stream matches the single-request reference exactly
    (greedy rows never consume PRNG), the hot slot still completes."""
    cfg, m, params = engine_setup
    prompt = np.asarray([5, 3, 2, 4], np.int32)
    eng = ServingEngine(m, params, slots=2, max_len=64)
    greedy = Request(uid=0, prompt=prompt, max_new_tokens=8)
    hot = Request(uid=1, prompt=prompt, max_new_tokens=8,
                  temperature=1.3, top_k=20)
    eng.submit(greedy)
    eng.submit(hot)
    eng.run()
    assert greedy.done and hot.done
    assert len(greedy.output) == 8 and len(hot.output) == 8
    assert greedy.output == m.reference_decode(params, prompt, 8)
    assert all(0 <= t < cfg.vocab_size for t in hot.output)


def test_batched_prefill_one_dispatch(engine_setup):
    """Stall admission: prompts landing in the same length bucket
    prefill several slots per jitted dispatch (prefill_batches <
    prefills). Recurrent archs bucket by exact length (padding is
    unsound through their state scan), so they pay one dispatch per
    distinct length."""
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=4, max_len=64,
                        admission="stall")
    for i in range(4):   # lengths 5..8 → all in the pow2-8 bucket
        eng.submit(Request(uid=i,
                           prompt=np.arange(5 + i, dtype=np.int32) + 1,
                           max_new_tokens=4))
    eng.run()
    assert eng.stats.prefills == 4
    expected = 4 if cfg.arch_type in ("ssm", "hybrid") else 1
    assert eng.stats.prefill_batches == expected


def test_chunked_admission_zero_extra_dispatches(engine_setup):
    """Dispatch-count regression: a long prompt arriving mid-decode is
    admitted and chunk-refilled with ZERO host dispatches beyond the
    megastep cadence (dispatches == megasteps; no prefill batches)."""
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=2, max_len=96, megastep_k=8,
                        prefill_chunk=8)
    eng.submit(Request(uid=0, prompt=np.arange(4, dtype=np.int32) + 1,
                       max_new_tokens=24))
    eng.step()                     # slot 0 is now mid-decode
    long_p = (np.arange(40) % (cfg.vocab_size - 1) + 1).astype(np.int32)
    req = Request(uid=1, prompt=long_p, max_new_tokens=4)
    eng.submit(req)
    eng.run()
    assert req.done
    assert req.output == m.reference_decode(params, long_p, 4,
                                            max_len=96)
    assert eng.stats.prefill_batches == 0          # no stall dispatches
    assert eng.stats.inscan_admissions == 2
    assert eng.stats.chunk_refills >= 1            # 40 > prefill_chunk=8


def test_engine_rejects_mismatched_prequantized_params():
    """quant_policy must describe what is actually served: handing the
    engine a tree already quantized in a different format raises
    instead of silently mislabeling (re-quantizing int weights would
    compound error)."""
    cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=1)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    q8 = quantize_tree(params, "q8_0", cfg.quant_group)
    with pytest.raises(ValueError, match="already quantized"):
        ServingEngine(m, q8, slots=1, max_len=64, quant_policy="q4_0")
    # matching policy is the documented no-op path — and it must
    # actually serve (catches quantize_tree descending into
    # QuantizedTensor nodes and nesting them)
    eng = ServingEngine(m, q8, slots=1, max_len=64, quant_policy="q8_0")
    assert eng.quant_policy == "q8_0"
    req = Request(uid=0, prompt=np.asarray([3, 1, 4], np.int32),
                  max_new_tokens=3)
    eng.submit(req)
    eng.run()
    assert req.done
    assert req.output == m.reference_decode(q8, req.prompt, 3)


def test_planner_picks_megastep_k():
    """Dispatch-overhead napkin math: K grows as the device step
    shrinks relative to the launch cost, the analytic serving model
    predicts the amortization win, and mixed-load admission planning
    picks chunked admission exactly when stalls cost more than riding."""
    from repro.core import (a17_cpu, choose_megastep_k, megastep_time,
                            simulate_admission, simulate_megastep)
    hw = a17_cpu(2)
    assert choose_megastep_k(hw, step_s=1.0) == 1       # step ≫ dispatch
    assert choose_megastep_k(hw, step_s=1e-5) > 1       # dispatch-bound
    assert choose_megastep_k(hw, step_s=0.0) == 1
    # mixed load: frequent arrivals cap K (admission waits on the scan)
    k_idle = choose_megastep_k(hw, step_s=1e-5)
    k_busy = choose_megastep_k(hw, step_s=1e-5, arrival_rate_per_s=1e4)
    assert 1 <= k_busy < k_idle

    ks = (1, 4, 8, 16)
    from repro.configs.paper_models import PAPER_MODELS
    import dataclasses as dc
    llama = PAPER_MODELS["llama3.2-1b"]
    fast = dc.replace(hw, dispatch_overhead_s=5e-3)     # dispatch-bound
    r = simulate_megastep(llama, fast, ks=ks)
    tps = [r[k].tokens_per_s for k in ks]
    assert tps == sorted(tps) and tps[-1] > tps[0]

    # donated carries: the un-donated boundary copy costs throughput
    t_d = megastep_time(1e-4, hw, 8, carry_bytes=1e9, donate_carries=True)
    t_n = megastep_time(1e-4, hw, 8, carry_bytes=1e9,
                        donate_carries=False)
    assert t_d < t_n
    r_nd = simulate_megastep(llama, fast, ks=(8,), donate_carries=False)
    assert r_nd[8].tokens_per_s < r[8].tokens_per_s

    # admission planning: dispatch-dominated admission-heavy traffic
    # (short prompts, unbatched stalls, short generations) → chunked
    # wins; cheap dispatch + very long prompts + perfect bucketing →
    # stall wins (one fused prefill pass beats 4096 rider substeps)
    heavy = dc.replace(hw, dispatch_overhead_s=5e-2)
    adm = simulate_admission(llama, heavy, k=8, batch=8, prompt_len=4,
                             max_new=8, prefill_bucket=1)
    assert adm["chunked"].tokens_per_s > 1.1 * adm["stall"].tokens_per_s
    cheap = dc.replace(hw, dispatch_overhead_s=1e-7)
    adm2 = simulate_admission(llama, cheap, k=8, batch=4,
                              prompt_len=4096, max_new=8,
                              prefill_bucket=4)
    assert adm2["stall"].tokens_per_s > adm2["chunked"].tokens_per_s


def test_plan_decode_sets_admission_and_donation():
    """The hardware-aware plan carries the serving-loop decisions."""
    from repro.core import TPU_V5E, plan
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("deepseek-7b")
    p = plan(cfg, INPUT_SHAPES["decode_32k"], TPU_V5E,
             avg_prompt_len=32)
    assert p.megastep_k >= 1
    assert p.admission in ("chunked", "stall")
    # donation pairs with depth: a pipelined plan must NOT donate (the
    # previous carry is still in flight when the next megastep wants
    # the buffer), a depth-1 plan always should
    assert p.donate_carries == (p.pipeline_depth < 2)
    assert "admission=" in p.summary()
    # precision is a first-class plan output: memory-bound decode on
    # TPU wants the 4.5-bit stream; the quality floor can veto it
    assert p.quant_policy == "q4_0"
    assert "quant=" in p.summary()
    assert p.config_overrides()["quant_policy"] == "q4_0"
    p_q8 = plan(cfg, INPUT_SHAPES["decode_32k"], TPU_V5E,
                avg_prompt_len=32, quality_floor_bits=8.0)
    assert p_q8.quant_policy == "q8_0"
    p_bf = plan(cfg, INPUT_SHAPES["decode_32k"], TPU_V5E,
                avg_prompt_len=32, allow_quant=False)
    assert p_bf.quant_policy == "bf16"


# ---------------------------------------------------------------------------
# KV-cache quantization (PR-4): structure, engine param, planner
# ---------------------------------------------------------------------------

KV_FORMATS = ("bf16", "q8_0", "q4_0")


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("kv", KV_FORMATS)
def test_cache_axes_match_cache_structure(arch, kv):
    """Regression (the PR-3 stale-aux bug class, for caches): every
    data leaf ``init_cache`` creates — including the new
    ``k_scale``/``v_scale`` leaves — must have a matching ``cache_axes``
    entry of the same rank naming a batch axis, across all four cache
    families × every kv format. A missing/short axis entry breaks the
    engine's prefill splicing and admission reset silently."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config(arch)), kv_quant=kv)
    m = Model(cfg)
    cache = m.init_cache(2, 64)
    axes = m.cache_axes()
    c_leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    a_leaves = jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple))[0]
    c_paths = [jax.tree_util.keystr(p) for p, _ in c_leaves]
    a_paths = [jax.tree_util.keystr(p) for p, _ in a_leaves]
    assert c_paths == a_paths, (arch, kv)
    for (path, leaf), (_, ax) in zip(c_leaves, a_leaves):
        assert len(ax) == leaf.ndim, (arch, kv, path, ax, leaf.shape)
        assert ax.count("batch") == 1, (arch, kv, path, ax)
    if kv != "bf16" and cfg.arch_type not in ("ssm", "hybrid"):
        assert any("k_scale" in p for p in c_paths), (arch, kv)


def test_engine_kv_quant_param_rebinds_model():
    """ServingEngine(kv_quant=...) on a bf16-config model serves a
    quantized cache (int8 leaves + scale siblings) and stays
    token-identical to the rebound model's reference loop."""
    cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=1)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="kv_quant"):
        ServingEngine(m, params, slots=1, max_len=64, kv_quant="int3")
    eng = ServingEngine(m, params, slots=2, max_len=64, megastep_k=4,
                        kv_quant="q8_0")
    assert eng.cfg.kv_quant == "q8_0" and eng.kv_quant == "q8_0"
    assert any(l.dtype == jnp.int8
               for l in jax.tree_util.tree_leaves(eng.cache))
    # bits/16: int8 payload + groupwise scales vs the bf16 cache
    bf16_eng = ServingEngine(m, params, slots=2, max_len=64)
    ratio = eng.cache_nbytes() / bf16_eng.cache_nbytes()
    assert abs(ratio - 8.5 / 16) < 0.02, ratio
    req = Request(uid=0, prompt=np.asarray([3, 1, 4, 1, 5], np.int32),
                  max_new_tokens=5)
    eng.submit(req)
    eng.run()
    assert req.output == eng.model.reference_decode(params, req.prompt, 5)


def test_engine_kv_quant_noop_for_recurrent():
    """kv_quant on an SSM engine changes nothing: same bf16 cache
    structure, same tokens."""
    cfg = reduced(get_config("mamba2-2.7b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    outs = {}
    for kv in ("bf16", "q4_0"):
        eng = ServingEngine(m, params, slots=1, max_len=64, kv_quant=kv)
        assert eng.kv_quant == "bf16"
        assert all(l.dtype != jnp.int8
                   for l in jax.tree_util.tree_leaves(eng.cache))
        req = Request(uid=0, prompt=np.asarray([2, 7, 1], np.int32),
                      max_new_tokens=4)
        eng.submit(req)
        eng.run()
        outs[kv] = req.output
    assert outs["bf16"] == outs["q4_0"]


def test_plan_and_simulator_carry_kv_quant():
    """dispatch.plan emits kv_quant beside megastep_k/admission/
    quant_policy (quality-floor veto + recurrent no-op), and
    simulate_kv_precision predicts the context-scaling win."""
    from repro.core import TPU_V5E, a17_cpu, plan, simulate_kv_precision
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("deepseek-7b")
    p = plan(cfg, INPUT_SHAPES["decode_32k"], TPU_V5E, avg_prompt_len=32)
    assert p.kv_quant == "q4_0"           # compute-rich TPU: 4.5 bits win
    assert "kv_quant=" in p.summary()
    # applying the plan to a ModelConfig must carry the cache precision
    # (config_overrides is the documented way to consume a plan)
    assert p.config_overrides()["kv_quant"] == "q4_0"
    p_floor = plan(cfg, INPUT_SHAPES["decode_32k"], TPU_V5E,
                   avg_prompt_len=32, quality_floor_bits=8.0)
    assert p_floor.kv_quant == "q8_0"
    p_off = plan(cfg, INPUT_SHAPES["decode_32k"], TPU_V5E,
                 avg_prompt_len=32, allow_quant=False)
    assert p_off.kv_quant == "bf16"
    p_train = plan(cfg, INPUT_SHAPES["train_4k"], TPU_V5E)
    assert p_train.kv_quant == "bf16"     # no decode loop to feed
    p_ssm = plan(get_config("mamba2-2.7b"), INPUT_SHAPES["decode_32k"],
                 TPU_V5E, avg_prompt_len=32)
    assert p_ssm.kv_quant == "bf16"       # recurrent: contract no-op

    hw = a17_cpu(2)
    sim = simulate_kv_precision(cfg, hw, kv_lens=(64, 32768), ks=(8,))
    gain = lambda fmt, kvl: (sim[fmt][kvl][8].tokens_per_s
                             / sim["bf16"][kvl][8].tokens_per_s)
    # the cache-stream win exists at long context and grows with it
    assert gain("q8_0", 32768) > 1.02
    assert gain("q8_0", 32768) > gain("q8_0", 64)
    assert gain("q4_0", 32768) > 1.0
    # recurrent families: all formats predict identically (no-op)
    simr = simulate_kv_precision(get_config("recurrentgemma-2b"), hw,
                                 kv_lens=(4096,), ks=(8,))
    assert simr["q4_0"][4096][8].tokens_per_s == \
        simr["bf16"][4096][8].tokens_per_s


def test_plan_kernel_backend_flips_quant_ordering():
    """The planner's kernel_backend knob predicts the fused-dequant
    flip this PR's kernels cause: priced against the XLA backend the
    materialized q4_0 unpack (write + read of a bf16 view) drowns the
    byte win and both weight and cache precision fall back to q8_0;
    priced against the fused Pallas kernels (in-register dequant,
    quantized-width HBM reads) q4_0 wins both. config_overrides emits
    a consistent (kernels, use_pallas) pair either way."""
    from repro.core import TPU_V5E, plan, simulate_kv_precision
    from repro.core.precision import get_format
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("deepseek-7b")
    shape = INPUT_SHAPES["decode_32k"]
    p_pal = plan(cfg, shape, TPU_V5E, avg_prompt_len=32)  # default
    p_xla = plan(cfg, shape, TPU_V5E, avg_prompt_len=32,
                 kernel_backend="xla")
    assert p_pal.kernel_backend == "pallas"
    assert (p_pal.quant_policy, p_pal.kv_quant) == ("q4_0", "q4_0")
    assert (p_xla.quant_policy, p_xla.kv_quant) == ("q8_0", "q8_0")
    assert "kernels=" in p_pal.summary()
    over_p, over_x = p_pal.config_overrides(), p_xla.config_overrides()
    assert over_p["kernels"] == "pallas" and over_p["use_pallas"]
    assert over_x["kernels"] == "xla" and not over_x["use_pallas"]
    with pytest.raises(ValueError):
        plan(cfg, shape, TPU_V5E, kernel_backend="mosaic")

    # the flip's mechanism, pinned at the format level: only q4_0
    # carries a materialized-unpack tax, so only its effective stream
    # ratio degrades under XLA (q8_0's int8 widen fuses into the dot)
    q4, q8 = get_format("q4_0"), get_format("q8_0")
    assert q4.effective_stream_ratio("pallas") == q4.stream_ratio
    assert q4.effective_stream_ratio("xla") > 1.0   # worse than bf16
    assert q8.effective_stream_ratio("xla") == q8.stream_ratio

    # and at the simulator level: per-backend q4-vs-q8 ordering at the
    # plan's context on the same hardware
    sim_p = simulate_kv_precision(cfg, TPU_V5E, kv_lens=(32768,),
                                  ks=(8,))
    sim_x = simulate_kv_precision(cfg, TPU_V5E, kv_lens=(32768,),
                                  ks=(8,), kernel_backend="xla")
    assert sim_p["q4_0"][32768][8].tokens_per_s > \
        sim_p["q8_0"][32768][8].tokens_per_s
    assert sim_x["q8_0"][32768][8].tokens_per_s > \
        sim_x["q4_0"][32768][8].tokens_per_s


# ---------------------------------------------------------------------------
# Paged KV cache (PR-9): planner knob, donation pairing, byte audit
# ---------------------------------------------------------------------------


def test_plan_never_pairs_pipelining_with_donation():
    """Regression: plan() used to hardcode donate_carries=True even
    when it chose pipeline_depth>1 — a donated carry can't be reused
    while the previous megastep still holds it in flight, so the
    engine had to warn and override at construction. The plan must
    never emit the pair: donation is on exactly when the decode loop
    is unpipelined."""
    from repro.core import TPU_V5E, a17_cpu, plan
    from repro.configs.base import INPUT_SHAPES
    for arch in ("deepseek-7b", "mistral-nemo-12b", "mamba2-2.7b"):
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            for hw in (TPU_V5E, a17_cpu(2)):
                for hit in (0.0, 0.6):
                    p = plan(cfg, shape, hw, avg_prompt_len=512,
                             prefix_hit_rate=hit)
                    assert not (p.pipeline_depth > 1
                                and p.donate_carries), \
                        (arch, shape.name, hit, p.summary())
                    assert p.donate_carries == (p.pipeline_depth < 2)


def test_engine_overrides_donation_when_pipelined():
    """The engine-side belt to the planner's suspenders: constructing
    a pipelined engine with donated carries warns and overrides to
    donate_carries=False instead of serving stale buffers."""
    import warnings
    cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=1)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    with pytest.warns(RuntimeWarning, match="donate"):
        eng = ServingEngine(m, params, slots=2, max_len=64,
                            pipeline_depth=2, donate_carries=True)
    assert eng.pipeline_depth == 2 and not eng.donate_carries
    # the consistent pair stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng2 = ServingEngine(m, params, slots=2, max_len=64,
                             pipeline_depth=2, donate_carries=False)
    assert not eng2.donate_carries
    # and the overridden engine still serves correctly
    req = Request(uid=0, prompt=np.asarray([3, 1, 4, 1, 5], np.int32),
                  max_new_tokens=5)
    eng.submit(req)
    eng.run()
    assert req.output == m.reference_decode(params, req.prompt, 5)


def test_plan_page_size_knob():
    """page_size is emitted only when prefix reuse beats the gather
    tax: 0 at the default (no-reuse) hit rate, a sweep size under
    prefix-heavy traffic, and always 0 for recurrent families."""
    from repro.core import TPU_V5E, plan, simulate_paging
    from repro.configs.base import INPUT_SHAPES
    cfg = get_config("deepseek-7b")
    shape = INPUT_SHAPES["decode_32k"]
    p0 = plan(cfg, shape, TPU_V5E, avg_prompt_len=512)
    assert p0.page_size == 0
    p_hit = plan(cfg, shape, TPU_V5E, avg_prompt_len=512,
                 prefix_hit_rate=0.6)
    assert p_hit.page_size > 0
    assert "page_size=" in p_hit.summary()
    p_ssm = plan(get_config("mamba2-2.7b"), shape, TPU_V5E,
                 avg_prompt_len=512, prefix_hit_rate=0.6)
    assert p_ssm.page_size == 0

    # the analytic twin behind the knob: paged pool bytes sit far
    # below the dense high-water prealloc at long context, and prefix
    # hits buy back rider substeps (none without hits)
    sim = simulate_paging(cfg, TPU_V5E, prompt_len=512, max_new=64,
                          kv_len=4096, hit_rate=0.6)
    assert 0 in sim and sim[0]["pool_bytes"] == sim[0]["dense_bytes"]
    for p in (8, 16, 32):
        assert sim[p]["pool_bytes"] < sim[0]["dense_bytes"]
        assert sim[p]["rider_substeps_saved"] > 0
    sim0 = simulate_paging(cfg, TPU_V5E, prompt_len=512, max_new=64,
                           kv_len=4096, hit_rate=0.0)
    assert all(sim0[p]["rider_substeps_saved"] == 0 for p in (8, 16, 32))
    # recurrent families degenerate to dense (nothing to page)
    simr = simulate_paging(get_config("mamba2-2.7b"), TPU_V5E,
                           prompt_len=512, max_new=64, kv_len=4096,
                           hit_rate=0.6)
    for p in (8, 16, 32):
        assert simr[p]["step"].tokens_per_s == simr[0]["step"].tokens_per_s
        assert simr[p]["rider_substeps_saved"] == 0


@pytest.mark.parametrize("page", (0, 8))
@pytest.mark.parametrize("kv", KV_FORMATS)
def test_cache_nbytes_matches_live_pytree(kv, page):
    """Satellite audit: cache_nbytes() — the number every BENCH
    section reports — equals the actual bytes of every live cache
    leaf (pools, block tables, scale planes, lens) for dense and
    paged caches across cache precisions."""
    cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=1)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, slots=2, max_len=64, kv_quant=kv,
                        page_size=page)
    leaves = jax.tree_util.tree_leaves(eng.cache)
    assert eng.cache_nbytes() == sum(int(np.asarray(l).nbytes)
                                     for l in leaves)
    if page:
        # the paged cache really is the pool+table layout: an int32
        # block table leaf exists and a right-sized pool undercuts the
        # dense slots*max_len prealloc
        assert any(l.dtype == jnp.int32 and l.ndim == 2 for l in leaves)
        small = ServingEngine(m, params, slots=2, max_len=64,
                              kv_quant=kv, page_size=page,
                              cache_blocks=2 * (16 // page) + 1)
        dense = ServingEngine(m, params, slots=2, max_len=64,
                              kv_quant=kv)
        assert small.cache_nbytes() < dense.cache_nbytes()
