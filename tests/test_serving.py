"""Serving engine + sampler behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serving import Request, SamplingConfig, ServingEngine, sample


@pytest.fixture(scope="module")
def engine_setup():
    cfg = reduced(get_config("deepseek-7b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def test_greedy_sampling_deterministic():
    logits = jnp.asarray([[0.0, 5.0, 1.0], [2.0, 0.0, -1.0]])
    out = sample(logits, jax.random.PRNGKey(0), SamplingConfig())
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    logits = jnp.asarray([[0.0, 10.0, 9.0, -50.0]])
    cfg = SamplingConfig(temperature=1.0, top_k=2)
    for seed in range(20):
        t = int(sample(logits, jax.random.PRNGKey(seed), cfg)[0])
        assert t in (1, 2)


def test_engine_completes_all_requests(engine_setup):
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=2, max_len=64)
    reqs = [Request(uid=i, prompt=np.arange(4, dtype=np.int32) + i + 1,
                    max_new_tokens=6) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 6 for r in reqs)
    assert eng.stats.prefills == 5


def test_engine_greedy_matches_manual_decode(engine_setup):
    """Engine output == hand-rolled prefill+decode loop (greedy)."""
    cfg, m, params = engine_setup
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    eng = ServingEngine(m, params, slots=1, max_len=64)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    eng.submit(req)
    eng.run()

    cache = m.init_cache(1, 64)
    logits, cache = m.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                              cache)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(3):
        logits, cache = m.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(logits[0])))
    assert req.output == toks


def test_engine_eos_stops_early(engine_setup):
    cfg, m, params = engine_setup
    eng = ServingEngine(m, params, slots=1, max_len=64)
    # discover the greedy first token, then use it as "EOS"
    probe = Request(uid=0, prompt=np.asarray([1, 2, 3], np.int32),
                    max_new_tokens=1)
    eng.submit(probe)
    eng.run()
    eos = probe.output[0]
    eng2 = ServingEngine(m, params, slots=1, max_len=64)
    req = Request(uid=1, prompt=np.asarray([1, 2, 3], np.int32),
                  max_new_tokens=50, eos_id=eos)
    eng2.submit(req)
    eng2.run()
    assert req.done and len(req.output) == 1


def test_sliding_window_archs_serve(engine_setup):
    """Hybrid (window) and ssm archs run the engine end-to-end."""
    for arch in ("recurrentgemma-2b", "mamba2-2.7b"):
        cfg = reduced(get_config(arch))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ServingEngine(m, params, slots=2, max_len=96)
        for i in range(3):
            eng.submit(Request(uid=i,
                               prompt=np.arange(6, dtype=np.int32) + 1,
                               max_new_tokens=5))
        eng.run()
        assert eng.stats.tokens_generated >= 15
