"""Training substrate: optimizer, convergence, accumulation, checkpoint."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.training import (AdamWConfig, DataConfig, TrainConfig, batches,
                            checkpoint, init_state, make_train_step,
                            schedule)


@pytest.fixture(scope="module")
def small():
    cfg = dataclasses.replace(reduced(get_config("deepseek-7b")),
                              param_dtype="f32")
    m = Model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]             # warmup
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[3] > lrs[4]                      # cosine decay
    assert lrs[4] >= 1e-4 * 0.99                # min_lr floor


def test_loss_decreases_on_copy_task(small):
    cfg, m, params = small
    tcfg = TrainConfig(adamw=AdamWConfig(lr=3e-3, warmup_steps=10,
                                         total_steps=300,
                                         weight_decay=0.0))
    step = jax.jit(make_train_step(m, tcfg))
    opt = init_state(params)
    it = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=16, kind="copy"))
    losses = []
    for i in range(60):
        b = next(it)
        params, opt, metrics = step(
            params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    assert all(np.isfinite(losses))


def test_grad_accumulation_matches_full_batch(small):
    cfg, m, params = small
    acfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                       grad_clip=1e9, weight_decay=0.0)
    step1 = jax.jit(make_train_step(m, TrainConfig(adamw=acfg,
                                                   microbatches=1)))
    step4 = jax.jit(make_train_step(m, TrainConfig(adamw=acfg,
                                                   microbatches=4)))
    b = next(batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                global_batch=8, kind="copy")))
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    opt = init_state(params)
    p1, _, m1 = step1(params, opt, batch)
    p4, _, m4 = step4(params, opt, batch)
    # micro-losses average to the same value; grads differ only through
    # per-microbatch loss normalization (same masks here) → params close
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    l1 = np.asarray(jax.tree_util.tree_leaves(p1)[0], np.float32)
    l4 = np.asarray(jax.tree_util.tree_leaves(p4)[0], np.float32)
    np.testing.assert_allclose(l1, l4, atol=5e-4)


def test_checkpoint_roundtrip(small, tmp_path):
    cfg, m, params = small
    opt = init_state(params)
    path = str(tmp_path / "ckpt.msgpack")
    checkpoint.save(path, {"params": params, "opt": opt, "step": 7})
    back = checkpoint.restore(path)
    assert back["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert isinstance(back["opt"], type(opt))


def test_checkpoint_quantized(tmp_path):
    from repro.quant import quantize_tree
    cfg = reduced(get_config("deepseek-7b"))
    m = Model(cfg)
    params = quantize_tree(m.init(jax.random.PRNGKey(0), quantize=False),
                           "q4_0")
    path = str(tmp_path / "q.msgpack")
    checkpoint.save(path, params)
    back = checkpoint.restore(path)
    flat_a = jax.tree_util.tree_leaves(params)
    flat_b = jax.tree_util.tree_leaves(back)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_lm_data_has_structure():
    """The synthetic stream must be learnable (bigram successor rule)."""
    it = batches(DataConfig(vocab_size=128, seq_len=64, global_batch=4,
                            kind="lm"))
    b = next(it)
    assert b["tokens"].shape == (4, 64)
    assert b["tokens"].max() < 128
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
