"""Quantization: round-trip bounds, packing, effective bits (paper fn.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.quant import (
    dequantize, dequantize_rows, kv_group_size, pack_int4,
    pack_int4_rows, quantize, quantize_q4_0, quantize_q8_0,
    quantize_rows, quantize_tree, unpack_int4, unpack_int4_rows,
)


@pytest.mark.parametrize("shape", [(32, 8), (64, 16), (128, 256), (4, 64, 32)])
@pytest.mark.parametrize("fmt,tol", [("q8_0", 0.02), ("q4_0", 0.12)])
def test_roundtrip_error_bound(shape, fmt, tol):
    w = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    qt = quantize(w, fmt)
    wd = dequantize(qt, jnp.float32)
    rel = np.abs(np.asarray(wd - w)).max() / np.abs(np.asarray(w)).max()
    assert rel < tol


def test_effective_bits_match_paper():
    # paper footnote 1: Q4 is "effective 4.5 bits/weight"
    w = jnp.ones((128, 64))
    q4 = quantize_q4_0(w)
    q8 = quantize_q8_0(w)
    assert q4.quant_nbytes / q4.logical_nbytes == pytest.approx(4.5 / 16)
    assert q8.quant_nbytes / q8.logical_nbytes == pytest.approx(8.5 / 16)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_pack_unpack_roundtrip(seed):
    q = jax.random.randint(jax.random.PRNGKey(seed), (32, 8), -8, 8,
                           jnp.int8)
    assert (unpack_int4(pack_int4(q)) == q).all()


@given(st.integers(0, 2**32 - 1), st.sampled_from(["q8_0", "q4_0"]))
@settings(max_examples=15, deadline=None)
def test_scale_invariance(seed, fmt):
    """Quantization error scales linearly with the tensor (groupwise
    scales are per-group max-abs): quantize(c*w) == c*quantize(w) for
    power-of-two c."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 16))
    c = 4.0
    d1 = dequantize(quantize(w, fmt), jnp.float32)
    d2 = dequantize(quantize(w * c, fmt), jnp.float32)
    np.testing.assert_allclose(np.asarray(d1) * c, np.asarray(d2),
                               rtol=1e-5, atol=1e-5)


def test_quantize_tree_idempotent():
    """Re-running quantize_tree over an already-quantized tree is a
    no-op. Regression: tree_map used to descend *into* QuantizedTensor
    pytree nodes and quantize their int8 payloads (nested
    QuantizedTensor → dequantize crashes at serving time)."""
    from repro.quant.quantize import QuantizedTensor
    params = {"w": jnp.ones((64, 32)), "norm": jnp.ones((32,))}
    once = quantize_tree(params, "q8_0")
    twice = quantize_tree(once, "q8_0")
    assert isinstance(twice["w"], QuantizedTensor)
    assert not isinstance(twice["w"].data, QuantizedTensor)
    assert twice["w"] is once["w"]
    np.testing.assert_array_equal(np.asarray(dequantize(twice["w"])),
                                  np.asarray(dequantize(once["w"])))


def test_quantize_tree_skips_norms_and_embeddings():
    params = {
        "embedding": jnp.ones((64, 32)),
        "layers": {"attn_norm": jnp.ones((32,)),
                   "wqkv": {"w": jnp.ones((32, 96))}},
    }
    qt = quantize_tree(params, "q4_0")
    assert isinstance(qt["embedding"], jnp.ndarray)
    assert isinstance(qt["layers"]["attn_norm"], jnp.ndarray)
    assert not isinstance(qt["layers"]["wqkv"]["w"], jnp.ndarray)


def test_quantized_tensor_is_pytree():
    qt = quantize_q4_0(jnp.ones((64, 16)))
    leaves = jax.tree_util.tree_leaves(qt)
    assert len(leaves) == 2
    out = jax.jit(lambda t: dequantize(t).sum())(qt)
    assert np.isfinite(float(out))


@pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
def test_shape_tracks_scan_over_layers_slicing(fmt):
    """Regression: a stacked (L, K, N) QuantizedTensor sliced by
    scan-over-layers must report the *sliced* logical shape. The old
    statically-stored ``shape`` aux field survived the slice unchanged
    (pytree children lose the leading dim; aux data doesn't), so
    ``.shape`` lied inside every scan body — ``logical_shape`` is now
    authoritative and ``shape`` aliases it."""
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 64, 16), jnp.float32)
    qt = quantize(w, fmt)
    assert qt.shape == (3, 64, 16)
    assert qt.logical_shape == (3, 64, 16)
    assert qt.ndim == 3 and qt.k_axis == 1

    seen = []

    def body(carry, q_l):
        seen.append((q_l.shape, q_l.logical_shape, q_l.ndim, q_l.k_axis))
        return carry + dequantize(q_l, jnp.float32).sum(), None

    total, _ = jax.lax.scan(body, 0.0, qt)
    assert seen == [((64, 16), (64, 16), 2, 0)]   # traced once, sliced
    want = dequantize(qt, jnp.float32).sum()
    np.testing.assert_allclose(float(total), float(want), rtol=1e-5)

    # manual per-layer indexing (the unroll_scans path) agrees too
    q0 = jax.tree_util.tree_map(lambda a: a[0], qt)
    assert q0.shape == (64, 16)
    np.testing.assert_allclose(np.asarray(dequantize(q0, jnp.float32)),
                               np.asarray(dequantize(qt, jnp.float32))[0])


# ---------------------------------------------------------------------------
# Row-wise (KV-cache) groupwise quantization
# ---------------------------------------------------------------------------

# Per-format round-trip tolerance table for KV rows (max relative
# error vs the row's own max-abs). q8_0: 1/254 quantization step +
# bf16 scale rounding; q4_0: 1/14 step dominates — same bounds as the
# weight-path table above, the grouping axis just moved to the row.
KV_ROUNDTRIP_TOL = {"q8_0": 0.02, "q4_0": 0.12}


@pytest.mark.parametrize("dim", [16, 24, 32, 48, 64, 96])
@pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
def test_kv_rows_roundtrip_error_bound(dim, fmt):
    """quantize_rows→dequantize_rows round-trip within the per-format
    tolerance, including non-group-aligned head dims (24, 48: not
    multiples of the default group 32)."""
    x = jax.random.normal(jax.random.PRNGKey(dim), (2, 3, 5, dim),
                          jnp.float32)
    payload, scales = quantize_rows(x, fmt)
    xr = dequantize_rows(payload, scales, fmt, jnp.float32)
    rel = np.abs(np.asarray(xr - x)).max() / np.abs(np.asarray(x)).max()
    assert rel < KV_ROUNDTRIP_TOL[fmt], (fmt, dim, rel)


def test_kv_group_size_rules():
    """Effective group: largest divisor of the row dim <= the nominal
    group; q4_0 needs an even dim to nibble-pack."""
    assert kv_group_size(64, 32, "q8_0") == 32
    assert kv_group_size(48, 32, "q8_0") == 24
    assert kv_group_size(20, 32, "q4_0") == 20
    assert kv_group_size(7, 32, "q8_0") == 7
    with pytest.raises(ValueError):
        kv_group_size(15, 32, "q4_0")
    with pytest.raises(ValueError):
        quantize_rows(jnp.ones((4, 15)), "q4_0")


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=15, deadline=None)
def test_pack_unpack_rows_roundtrip(seed):
    q = jax.random.randint(jax.random.PRNGKey(seed), (3, 4, 32), -8, 8,
                           jnp.int8)
    assert (unpack_int4_rows(pack_int4_rows(q)) == q).all()


def test_kv_rows_bytes_match_format_bits():
    """Payload + scale bytes per cached position = bits_per_weight/16
    of the bf16 footprint (paper fn.1 applied to the cache stream)."""
    x = jnp.ones((4, 64))
    bf16_bytes = x.size * 2
    for fmt, bits in (("q8_0", 8.5), ("q4_0", 4.5)):
        payload, scales = quantize_rows(x, fmt)
        nbytes = (payload.size * payload.dtype.itemsize
                  + scales.size * scales.dtype.itemsize)
        assert nbytes / bf16_bytes == pytest.approx(bits / 16)


def test_kv_rows_positionwise_independence():
    """Each row quantizes independently (scale depends only on its own
    values) — the property that makes fused-prefill and stepwise cache
    writes produce bit-identical quantized leaves."""
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 32), jnp.float32)
    p_full, s_full = quantize_rows(x, "q8_0")
    for i in range(x.shape[1]):
        p_i, s_i = quantize_rows(x[:, i], "q8_0")
        np.testing.assert_array_equal(np.asarray(p_full[:, i]),
                                      np.asarray(p_i))
        np.testing.assert_array_equal(
            np.asarray(s_full[:, i], np.float32),
            np.asarray(s_i, np.float32))


def test_quantized_tensor_checkpoint_roundtrip(tmp_path):
    """QuantizedTensor survives save/restore with the derived-shape
    protocol (older checkpoints stored a redundant shape field)."""
    from repro.training import checkpoint
    qt = quantize(jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16)),
                  "q4_0")
    path = str(tmp_path / "q.msgpack")
    checkpoint.save(path, {"w": qt})
    back = checkpoint.restore(path)["w"]
    assert back.fmt == qt.fmt and back.group == qt.group
    assert back.shape == qt.shape == (2, 64, 16)
    np.testing.assert_array_equal(np.asarray(back.data),
                                  np.asarray(qt.data))
    np.testing.assert_array_equal(np.asarray(back.scales, np.float32),
                                  np.asarray(qt.scales, np.float32))


def test_quantize_tree_leaf_pin_dense_model():
    """Pin exactly which leaves of a dense model quantize (the contract
    quantize_tree's docstring promises): matmul weights with ndim >= 2
    and group-aligned K do; anything on a ``norm`` or ``embed`` path —
    including the gather-read embedding table — stays a plain array,
    and a predicate can only restrict the selection, never re-enable a
    skipped path."""
    from repro.configs import get_config, reduced
    from repro.models import Model
    from repro.quant.quantize import QuantizedTensor
    cfg = reduced(get_config("deepseek-7b"), d_model=64, d_ff=128,
                  vocab_size=256, num_heads=2, num_kv_heads=1)
    params = Model(cfg).init(jax.random.PRNGKey(0))
    qt = quantize_tree(params, "q8_0", cfg.quant_group)
    flat = {jax.tree_util.keystr(p): l
            for p, l in jax.tree_util.tree_flatten_with_path(
                qt, is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]}
    quantized = {p for p, l in flat.items()
                 if isinstance(l, QuantizedTensor)}
    plain = set(flat) - quantized
    for p in quantized:
        assert "embed" not in p and "norm" not in p, p
        assert flat[p].logical_shape[-2] % cfg.quant_group == 0, p
    for p in plain:
        leaf = flat[p]
        assert ("embed" in p or "norm" in p or leaf.ndim < 2
                or leaf.shape[-2] % cfg.quant_group != 0), p
    assert any("embed" in p for p in plain)       # table stayed bf16
    assert quantized                              # ...but GEMMs moved
    # predicate restricts but cannot re-enable embed/norm paths
    qt2 = quantize_tree(params, "q8_0", cfg.quant_group,
                        predicate=lambda path, leaf: True)
    flat2 = {jax.tree_util.keystr(p): l
             for p, l in jax.tree_util.tree_flatten_with_path(
                 qt2, is_leaf=lambda x: isinstance(x, QuantizedTensor)
             )[0]}
    assert {p for p, l in flat2.items()
            if isinstance(l, QuantizedTensor)} == quantized
    qt3 = quantize_tree(params, "q8_0", cfg.quant_group,
                        predicate=lambda path, leaf: False)
    assert not any(isinstance(l, QuantizedTensor)
                   for l in jax.tree_util.tree_leaves(
                       qt3, is_leaf=lambda x: isinstance(
                           x, QuantizedTensor)))


def test_quant_matmul_shape_errors_are_informative():
    """The kernel's guard rails raise ValueError with the offending
    shapes instead of bare asserts (debuggability when dispatch hands
    it a bad tile)."""
    from repro.kernels.quant_matmul import quant_matmul
    x = jnp.ones((4, 64), jnp.float32)
    w = quantize_q8_0(jnp.ones((64, 32)))
    bad_x = jnp.ones((4, 32), jnp.float32)
    with pytest.raises(ValueError, match=r"64.*|32.*"):
        quant_matmul(bad_x, w, bm=4, bn=32, bk=32, interpret=True)
    with pytest.raises(ValueError, match="group"):
        quant_matmul(x, w, bm=4, bn=32, bk=16, interpret=True)
    with pytest.raises(ValueError, match="divide"):
        quant_matmul(x, w, bm=3, bn=32, bk=64, interpret=True)
