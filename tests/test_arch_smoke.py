"""Per-assigned-architecture smoke tests (deliverable f).

Each test instantiates a REDUCED variant of the same family (2-3
layers, d_model <= 512, <= 4 experts) and runs one forward/train step
plus a decode step on CPU, asserting output shapes and no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.models import Model
from repro.training import (AdamWConfig, TrainConfig, init_state,
                            make_train_step)


def _batch(cfg, B=2, S=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.arch_type == "audio":
        batch["frames"] = jax.random.normal(
            ks[1], (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.arch_type == "vlm":
        batch["prefix"] = 0.1 * jax.random.normal(
            ks[1], (B, cfg.num_prefix_embeddings, cfg.d_model),
            jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch))
            m = Model(cfg)
            cache[arch] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_no_nans(models, arch):
    cfg, m, params = models(arch)
    B, S = 2, 16
    logits, aux = m.forward(params, _batch(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(models, arch):
    cfg, m, params = models(arch)
    step = jax.jit(make_train_step(
        m, TrainConfig(adamw=AdamWConfig(warmup_steps=1, total_steps=10))))
    opt = init_state(params)
    batch = _batch(cfg)
    batch["labels"] = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step_shapes(models, arch):
    cfg, m, params = models(arch)
    B = 2
    cache = m.init_cache(B, 64)
    logits, cache = m.prefill(params, _batch(cfg, B, 8), cache)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache2 = m.decode_step(params, tok, cache)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-2.7b",
                                  "recurrentgemma-2b"])
def test_decode_matches_forward(models, arch):
    """prefill + decode == full forward (last-token logits)."""
    cfg, m, params = models(arch)
    B, S, G = 2, 12, 2
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S + G), 0,
                              cfg.vocab_size)
    cache = m.init_cache(B, 64)
    logits, cache = m.prefill(params, {"tokens": toks[:, :S]}, cache)
    for t in range(G):
        logits, cache = m.decode_step(params, toks[:, S + t:S + t + 1],
                                      cache)
    full, _ = m.forward(params, {"tokens": toks})
    a = np.asarray(logits, np.float32)
    b = np.asarray(full[:, -1], np.float32)
    assert np.abs(a - b).max() / (np.abs(b).max() + 1e-6) < 0.05


def test_quantized_model_forward():
    cfg = dataclasses.replace(reduced(get_config("deepseek-7b")),
                              quant_policy="q4_0")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    logits, _ = m.forward(params, _batch(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_quantized_matches_bf16_closely():
    cfg = reduced(get_config("deepseek-7b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0), quantize=False)
    from repro.quant import quantize_tree
    q8 = quantize_tree(params, "q8_0")
    batch = _batch(cfg)
    l_bf16, _ = m.forward(params, batch)
    l_q8, _ = Model(dataclasses.replace(cfg, quant_policy="q8_0")
                    ).forward(q8, batch)
    a = np.asarray(l_bf16, np.float32)
    b = np.asarray(l_q8, np.float32)
    assert np.abs(a - b).max() / (np.abs(a).max() + 1e-6) < 0.15


def test_exact_assigned_configs():
    """The full configs must carry the exact assigned hyperparameters."""
    import repro.configs as C
    spec = {
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128, d_ff=0),
        "qwen1.5-110b": dict(num_layers=80, d_model=8192, num_heads=64,
                             num_kv_heads=8, d_ff=49152,
                             vocab_size=152064, qkv_bias=True),
        "paligemma-3b": dict(num_layers=18, d_model=2048, num_heads=8,
                             num_kv_heads=1, d_ff=16384,
                             vocab_size=257216),
        "seamless-m4t-medium": dict(num_layers=12, d_model=1024,
                                    num_heads=16, num_kv_heads=16,
                                    d_ff=4096, vocab_size=256206,
                                    is_encoder_decoder=True),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168,
                                num_heads=64, num_kv_heads=8, d_ff=2048,
                                vocab_size=163840, num_experts=384,
                                experts_per_token=8),
        "deepseek-7b": dict(num_layers=30, d_model=4096, num_heads=32,
                            num_kv_heads=32, d_ff=11008,
                            vocab_size=102400),
        "mistral-nemo-12b": dict(num_layers=40, d_model=5120,
                                 num_heads=32, num_kv_heads=8,
                                 d_ff=14336, vocab_size=131072),
        "phi3.5-moe-42b-a6.6b": dict(num_layers=32, d_model=4096,
                                     num_heads=32, num_kv_heads=8,
                                     d_ff=6400, vocab_size=32064,
                                     num_experts=16, experts_per_token=2),
        "deepseek-67b": dict(num_layers=95, d_model=8192, num_heads=64,
                             num_kv_heads=8, d_ff=22016,
                             vocab_size=102400),
        "recurrentgemma-2b": dict(num_layers=26, d_model=2560,
                                  num_heads=10, num_kv_heads=1,
                                  d_ff=7680, vocab_size=256000),
    }
    for arch, wants in spec.items():
        cfg = C.get_config(arch)
        for k, v in wants.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_plausible():
    """Sanity: parameter counts land near the headline sizes."""
    expect = {
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "deepseek-7b": (6.5e9, 7.5e9),
        "deepseek-67b": (63e9, 70e9),
        "qwen1.5-110b": (100e9, 120e9),
        "mistral-nemo-12b": (11e9, 13.5e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
        "phi3.5-moe-42b-a6.6b": (39e9, 45e9),
        "recurrentgemma-2b": (2.3e9, 3.3e9),
        "paligemma-3b": (2.2e9, 3.2e9),    # language tower only (stub ViT)
        "seamless-m4t-medium": (0.5e9, 1.4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n / 1e9)
    # MoE active params
    kimi = get_config("kimi-k2-1t-a32b")
    active = kimi.param_count(active_only=True)
    assert 25e9 <= active <= 40e9, active / 1e9
