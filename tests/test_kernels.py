"""Per-kernel interpret-mode allclose sweeps vs the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.quant import quantize_q4_0, quantize_q8_0


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(128, 256, 128), (8, 64, 128),
                                 (256, 512, 384), (64, 1024, 64)])
@pytest.mark.parametrize("quant", [quantize_q8_0, quantize_q4_0])
@pytest.mark.parametrize("xdtype", [jnp.bfloat16, jnp.float32])
def test_quant_matmul_allclose(mkn, quant, xdtype):
    M, K, N = mkn
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (M, K), jnp.float32).astype(xdtype)
    w = quant(jax.random.normal(k2, (K, N), jnp.float32))
    bm, bn, bk = min(128, M), min(128, N), min(256, K)
    out = quant_matmul(x, w, bm=bm, bn=bn, bk=bk, interpret=True,
                       out_dtype=jnp.float32)
    want = ref.quant_matmul_ref(x, w, out_dtype=jnp.float32)
    scale = np.abs(np.asarray(want)).max() + 1e-6
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=0.02 * scale, rtol=0.05)


@pytest.mark.parametrize("m", [1, 2, 3, 4])   # decode: M = active slots
@pytest.mark.parametrize("kn", [(32, 48),     # single group, N unaligned
                                (96, 80),     # K, N both non-128-aligned
                                (64, 128)])   # group boundary K
@pytest.mark.parametrize("quant,fmt_tol",
                         [(quantize_q8_0, 0.05), (quantize_q4_0, 0.25)])
def test_quant_matmul_decode_shapes(m, kn, quant, fmt_tol):
    """Serving decode GEMVs: tiny M (one row per active slot),
    group-boundary and non-128-aligned K/N. Checked two ways: against
    the dequantize+einsum reference (near-exact — the kernel performs
    the same dequant arithmetic in f32) and against the *unquantized*
    matmul with per-format tolerances (the §5.3 quality cost)."""
    K, N = kn
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k1, (m, K), jnp.float32)
    wf = jax.random.normal(k2, (K, N), jnp.float32)
    w = quant(wf)
    out = quant_matmul(x, w, bm=m, bn=N, bk=K, interpret=True,
                       out_dtype=jnp.float32)
    want = ref.quant_matmul_ref(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    exact = np.asarray(jnp.einsum("mk,kn->mn", x, wf))
    scale = np.abs(exact).max() + 1e-6
    np.testing.assert_allclose(np.asarray(out), exact,
                               atol=fmt_tol * scale)


@pytest.mark.parametrize("quant", [quantize_q8_0, quantize_q4_0])
def test_quant_matmul_stacked_layer_slices(quant):
    """Scan-over-layers serving path: a stacked (L, K, N) quantized
    weight sliced per layer must matmul identically to quantizing each
    layer independently (slicing only drops the leading dim — data,
    scales and the derived logical shape all stay consistent)."""
    L, M, K, N = 3, 2, 64, 48
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    x = jax.random.normal(k1, (M, K), jnp.float32)
    wf = jax.random.normal(k2, (L, K, N), jnp.float32)
    stacked = quant(wf)
    for i in range(L):
        w_i = jax.tree_util.tree_map(lambda a: a[i], stacked)
        assert w_i.logical_shape == (K, N)
        out = quant_matmul(x, w_i, bm=M, bn=N, bk=K, interpret=True,
                           out_dtype=jnp.float32)
        want = quant_matmul(x, quant(wf[i]), bm=M, bn=N, bk=K,
                            interpret=True, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


def test_quant_matmul_grid_tiling_exact():
    """Tiling must not change results vs a single-tile call."""
    M, K, N = 256, 512, 256
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = quantize_q8_0(jax.random.normal(k2, (K, N), jnp.float32))
    a = quant_matmul(x, w, bm=64, bn=64, bk=128, interpret=True,
                     out_dtype=jnp.float32)
    b = quant_matmul(x, w, bm=256, bn=256, bk=512, interpret=True,
                     out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

CASES = [
    # B, Hq, Hkv, Sq, Skv, D, window, q_offset
    (2, 4, 2, 256, 256, 64, 0, 0),
    (1, 8, 1, 128, 128, 32, 0, 0),       # MQA
    (2, 4, 4, 256, 256, 64, 64, 0),      # sliding window
    (1, 2, 1, 128, 256, 64, 0, 128),     # q offset (chunked prefill)
    (1, 2, 2, 64, 64, 128, 16, 0),       # tiny window
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_allclose(case, dtype):
    B, Hq, Hkv, Sq, Skv, D, win, off = case
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=True, window=win, q_offset=off,
                          bq=64, bk=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, window=win,
                             q_offset=off)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@given(st.integers(0, 2**31), st.sampled_from([32, 64]),
       st.sampled_from([1, 2, 4]))
@settings(max_examples=10, deadline=None)
def test_flash_attention_property(seed, bq, g):
    """Property: rows attend only within the causal window — permuting
    *future* keys never changes the output."""
    B, Hkv, S, D = 1, 2, 128, 32
    Hq = Hkv * g
    ks = jax.random.split(jax.random.PRNGKey(seed % (2**31)), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bq,
                          interpret=True)
    # shuffle keys in the strictly-future half for the first query row
    row = S // 2 - 1
    perm = np.arange(S)
    perm[S // 2:] = perm[S // 2:][::-1]
    out2 = flash_attention(q, k[:, :, perm], v[:, :, perm], causal=True,
                           bq=bq, bk=bq, interpret=True)
    np.testing.assert_allclose(np.asarray(out[:, :, :row + 1]),
                               np.asarray(out2[:, :, :row + 1]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    (2, 8, 2, 256, 64, 0), (3, 4, 4, 512, 32, 0), (2, 8, 1, 256, 64, 128),
    (1, 16, 2, 128, 128, 0),
])
def test_decode_attention_allclose(case):
    B, Hq, Hkv, S, D, win = case
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    lens = jnp.asarray(([S // 2, S] + [S // 4] * B)[:B], jnp.int32)
    out = decode_attention(q, k, v, lens, window=win, bk=64,
                           interpret=True)
    want = ref.decode_attention_ref(q, k, v, kv_len=lens, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5)


def test_decode_attention_ignores_stale_cache():
    """Entries past kv_len must not affect the result."""
    B, Hq, Hkv, S, D = 2, 4, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, Hq, D))
    k = jax.random.normal(ks[1], (B, Hkv, S, D))
    v = jax.random.normal(ks[2], (B, Hkv, S, D))
    lens = jnp.asarray([100, 17], jnp.int32)
    out1 = decode_attention(q, k, v, lens, bk=64, interpret=True)
    k2 = k.at[:, :, 200:].set(1e4)   # poison stale region
    v2 = v.at[:, :, 200:].set(-1e4)
    out2 = decode_attention(q, k2, v2, lens, bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_xla_fallback_matches_kernel():
    """ops.decode_attention's bf16 jnp path == Pallas kernel."""
    from repro.kernels import ops
    B, Hq, Hkv, S, D = 2, 8, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
    lens = jnp.asarray([64, 128], jnp.int32)
    a = ops.decode_attention(q, k, v, lens, use_pallas=False)
    b = ops.decode_attention(q, k, v, lens, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# Quantized KV ring buffer (cache-write point of decode_step / prefill)
# ---------------------------------------------------------------------------

def _ring_cfg(head_dim, num_kv_heads=2, quant_group=32):
    from repro.configs.base import ModelConfig
    return ModelConfig(name="ring-test", d_model=head_dim * num_kv_heads,
                       num_heads=num_kv_heads, num_kv_heads=num_kv_heads,
                       head_dim=head_dim, quant_group=quant_group)


@pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
@pytest.mark.parametrize("window,head_dim", [(4, 32),   # aligned
                                             (5, 24),   # both unaligned
                                             (7, 48)])  # window > group? no:
def test_quantized_ring_wraparound_writes(fmt, window, head_dim):
    """Sliding-window ring semantics survive quantization: writing more
    rows than the window holds leaves each final slot equal to the
    round-trip quantization of the *last* row written there, including
    non-group-aligned window and head dims. The expectation quantizes
    under jit like the write path does — XLA-CPU's compiled division
    breaks exact .5 rounding ties differently from the eager op (see
    quantize_rows), and bf16 rows hit such ties routinely."""
    from repro.models import attention as attn
    from repro.quant import dequantize_rows, quantize_rows
    cfg = _ring_cfg(head_dim)
    B, Hkv, hd = 2, cfg.num_kv_heads, cfg.head_dim
    cache = attn.init_kv_cache(cfg, B, max_len=64, window=window,
                               kv_quant=fmt)
    assert cache["k"].dtype == jnp.int8
    n_writes = 3 * window  # wraps the ring twice
    rows = jax.random.normal(jax.random.PRNGKey(0),
                             (n_writes, B, Hkv, hd), jnp.bfloat16)
    write = jax.jit(lambda c, k, v, slot: attn.kv_cache_write(
        c, k, v, slot, kv_quant=fmt, group=cfg.quant_group))
    roundtrip = jax.jit(lambda x: dequantize_rows(
        *quantize_rows(x, fmt, cfg.quant_group), fmt))
    for i in range(n_writes):
        slot = jnp.full((B,), i % window, jnp.int32)
        cache = dict(cache, **write(cache, rows[i], rows[i], slot))
    k_read, _ = attn.kv_cache_read(cache, kv_quant=fmt)
    for s in range(window):
        last = n_writes - window + s  # last write landing in slot s
        np.testing.assert_array_equal(
            np.asarray(k_read[:, :, s], np.float32),
            np.asarray(roundtrip(rows[last]), np.float32))


@pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
def test_quantized_prefill_write_matches_stepwise(fmt):
    """The fused-prefill cache write (_write_prefill_kv) and the
    one-row-at-a-time decode write produce bit-identical quantized
    leaves — the invariant that keeps both admission modes pinned to
    the same reference stream (each position's scale depends only on
    its own values)."""
    from repro.models import attention as attn
    from repro.models.model import _write_prefill_kv
    cfg = _ring_cfg(32)
    B, Hkv, hd, S = 2, cfg.num_kv_heads, cfg.head_dim, 6
    kv = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, S, hd),
                           jnp.bfloat16)
    fused = attn.init_kv_cache(cfg, B, max_len=8, kv_quant=fmt)
    fused = jax.jit(lambda c, x: _write_prefill_kv(
        c, x, x, S, kv_quant=fmt, group=cfg.quant_group))(fused, kv)
    step = attn.init_kv_cache(cfg, B, max_len=8, kv_quant=fmt)
    write = jax.jit(lambda c, x, slot: attn.kv_cache_write(
        c, x, x, slot, kv_quant=fmt, group=cfg.quant_group))
    for i in range(S):
        slot = jnp.full((B,), i, jnp.int32)
        step = dict(step, **write(step, kv[:, :, i], slot))
    for name in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(
            np.asarray(fused[name][:, :, :S], np.float32),
            np.asarray(step[name][:, :, :S], np.float32), err_msg=name)


def test_bf16_ring_write_read_unchanged():
    """kv_cache_write/read on a bf16 cache are the plain set/passthrough
    the pre-kv-quant decode path used."""
    from repro.models import attention as attn
    cfg = _ring_cfg(32)
    B = 2
    cache = attn.init_kv_cache(cfg, B, max_len=4)
    row = jax.random.normal(jax.random.PRNGKey(2),
                            (B, cfg.num_kv_heads, cfg.head_dim),
                            jnp.bfloat16)
    cache = dict(cache, **attn.kv_cache_write(
        cache, row, row, jnp.zeros((B,), jnp.int32)))
    k_read, v_read = attn.kv_cache_read(cache)
    assert k_read is cache["k"] and v_read is cache["v"]
    np.testing.assert_array_equal(np.asarray(k_read[:, :, 0], np.float32),
                                  np.asarray(row, np.float32))


# ---------------------------------------------------------------------------
# decode attention over quantized cache leaves (fused dequant kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    # B, Hq, Hkv, S, D, win, group
    (2, 8, 2, 128, 64, 0, 32),    # full cache, GQA
    (2, 4, 2, 128, 24, 0, 8),     # non-group-aligned head dim
    (1, 8, 1, 256, 64, 64, 32),   # sliding window
    (3, 4, 4, 64, 32, 32, 16),    # MHA, window = ring size
])
@pytest.mark.parametrize("fmt", ["q8_0", "q4_0"])
def test_decode_attention_quant_parity(case, fmt):
    """Fused-dequant kernel == dequantize_rows + the XLA decode oracle,
    on the same quantized leaves — incl. part-filled and empty
    (kv_len=0) rows, sliding windows and non-group-aligned head dims."""
    from repro.kernels import ops
    from repro.kernels.decode_attention_quant import decode_attention_quant
    from repro.quant import quantize_rows
    B, Hq, Hkv, S, D, win, group = case
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kf = jax.random.normal(ks[1], (B, Hkv, S, D)).astype(jnp.bfloat16)
    vf = jax.random.normal(ks[2], (B, Hkv, S, D)).astype(jnp.bfloat16)
    kq, ksc = quantize_rows(kf, fmt, group)
    vq, vsc = quantize_rows(vf, fmt, group)
    lens = jnp.asarray(([0, S // 2, S] + [S // 4] * B)[:B], jnp.int32)
    out = decode_attention_quant(q, kq, ksc, vq, vsc, lens, fmt=fmt,
                                 window=win, bk=64, interpret=True)
    want = ops.decode_attention_quant(q, kq, ksc, vq, vsc, lens,
                                      fmt=fmt, window=win,
                                      use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-5)


def test_decode_attention_quant_kv_len_zero_rows():
    """A fully-empty row decodes to zeros on both paths (the l==0
    guard), while a neighbouring full row is unaffected."""
    from repro.kernels import ops
    from repro.quant import quantize_rows
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kf = jax.random.normal(ks[1], (B, Hkv, S, D)).astype(jnp.bfloat16)
    vf = jax.random.normal(ks[2], (B, Hkv, S, D)).astype(jnp.bfloat16)
    kq, ksc = quantize_rows(kf, "q8_0", 32)
    vq, vsc = quantize_rows(vf, "q8_0", 32)
    lens = jnp.asarray([0, S], jnp.int32)
    out = ops.decode_attention_quant(q, kq, ksc, vq, vsc, lens,
                                     fmt="q8_0", use_pallas=True)
    want = ops.decode_attention_quant(q, kq, ksc, vq, vsc, lens,
                                      fmt="q8_0", use_pallas=False)
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.zeros((Hq, D), np.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-5)


def test_decode_attention_quant_ring_wraparound_cache():
    """Quantized sliding-window ring cache: leaves written through
    kv_cache_write (wrapping the ring twice) read identically through
    the fused kernel and the dequantize_rows + oracle path."""
    from repro.kernels import ops
    from repro.models import attention as attn
    from repro.configs.base import ModelConfig
    window, hd, Hkv, B = 8, 32, 2, 2
    cfg = ModelConfig(name="ringq", d_model=hd * Hkv, num_heads=Hkv * 2,
                      num_kv_heads=Hkv, head_dim=hd, quant_group=32)
    cache = attn.init_kv_cache(cfg, B, max_len=64, window=window,
                               kv_quant="q4_0")
    n_writes = 2 * window + 3
    rows = jax.random.normal(jax.random.PRNGKey(23),
                             (n_writes, B, Hkv, hd), jnp.bfloat16)
    for i in range(n_writes):
        slot = jnp.full((B,), i % window, jnp.int32)
        cache = dict(cache, **attn.kv_cache_write(
            cache, rows[i], rows[i], slot, kv_quant="q4_0",
            group=cfg.quant_group))
    q = jax.random.normal(jax.random.PRNGKey(29),
                          (B, cfg.num_heads, hd), jnp.float32)
    lens = jnp.full((B,), window, jnp.int32)  # ring full: all slots valid
    args = (q, cache["k"], cache["k_scale"], cache["v"],
            cache["v_scale"], lens)
    out = ops.decode_attention_quant(*args, fmt="q4_0", use_pallas=True)
    want = ops.decode_attention_quant(*args, fmt="q4_0",
                                      use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-5)


def test_decode_attention_quant_rejects_bad_inputs():
    from repro.kernels.decode_attention_quant import decode_attention_quant
    q = jnp.zeros((1, 4, 32))
    kq = jnp.zeros((1, 1, 64, 32), jnp.int8)
    sc = jnp.zeros((1, 1, 64, 1), jnp.bfloat16)
    with pytest.raises(ValueError, match="fmt"):
        decode_attention_quant(q, kq, sc, kq, sc, 8, fmt="bf16")
    with pytest.raises(ValueError, match="payload"):
        # q4_0 payload should be D//2 = 16 wide, not 32
        decode_attention_quant(q, kq, sc, kq, sc, 8, fmt="q4_0")


# ---------------------------------------------------------------------------
# tile dispatch (_pick_tile / _pick_lane_tile) and env parsing
# ---------------------------------------------------------------------------

def test_pick_tile_lane_alignment():
    """Lane (minor) dims must tile 128-aligned or span the whole dim;
    the old picker handed Mosaic degenerate tiles (bn=29 for 493) that
    only worked in interpret mode."""
    from repro.kernels.ops import _pick_lane_tile, _pick_tile
    assert _pick_tile(512, 256) == 256
    assert _pick_tile(493, 128) == 29          # generic divisor picker
    assert _pick_lane_tile(493, 128) is None   # ...lane guard rejects it
    assert _pick_lane_tile(256, 128) == 128
    assert _pick_lane_tile(64, 128) == 64      # full-span, sublane-ok
    assert _pick_lane_tile(24, 128, multiple=8) == 24
    assert _pick_lane_tile(12, 128) is None    # not 8-aligned
    assert _pick_lane_tile(384, 128) == 128
    # group multiple must survive the lane constraint
    assert _pick_lane_tile(256, 256, multiple=32) == 256
    assert _pick_lane_tile(96, 128, multiple=32) == 96


@pytest.mark.parametrize("mkn", [(1, 64, 64),      # decode GEMV, bm=M=1
                                 (12, 64, 64),     # sublane-padded bm
                                 (2, 64, 93),      # prime-ish N -> XLA
                                 (3, 36, 64)])     # misaligned K -> XLA
@pytest.mark.parametrize("quant", [quantize_q8_0, quantize_q4_0])
def test_matmul_dispatch_misaligned_shapes(mkn, quant):
    """ops.matmul must stay correct whichever side of the tile-dispatch
    guard a shape lands on (fused kernel or XLA fallback)."""
    from repro.kernels import ops
    M, K, N = mkn
    k1, k2 = jax.random.split(jax.random.PRNGKey(31))
    x = jax.random.normal(k1, (M, K), jnp.float32)
    wf = jax.random.normal(k2, (K, N), jnp.float32)
    if K % 32:
        w = quant(wf, group=K)   # degenerate group for tiny K
    else:
        w = quant(wf)
    out = ops.matmul(x, w, use_pallas=True, out_dtype=jnp.float32)
    want = ref.quant_matmul_ref(x, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("val,expected", [
    ("1", True), ("true", True), ("TRUE", True), ("yes", True),
    ("0", False), ("false", False), ("False", False), ("FALSE", False),
    ("no", False), ("off", False), ("OFF", False), (" 0 ", False),
    ("", False),
])
def test_interpret_default_env_parsing(val, expected, monkeypatch):
    """REPRO_PALLAS_INTERPRET=False/FALSE/no/off must disable interpret
    mode (the old truthiness check treated any non-empty string as
    enabled)."""
    from repro.kernels import ops
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", val)
    assert ops._interpret_default() is expected


def test_interpret_default_unset_follows_backend(monkeypatch):
    from repro.kernels import ops
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert ops._interpret_default() is (jax.default_backend() != "tpu")
